//! Symbolic factorization, supernodes and the weighted assembly tree.
//!
//! This is the analysis phase of a multifrontal solver (paper §3): from
//! the matrix pattern we compute the pattern of `L` column by column
//! (up-looking, guided by the elimination tree), merge columns with
//! (near-)identical structure into **supernodes**, optionally
//! *amalgamate* small supernodes into their parents (trading a little
//! fill for larger fronts, as real solvers do), and emit the **assembly
//! tree**: one malleable task per supernode, weighted by the flops of
//! its partial frontal factorization — exactly the task trees the
//! paper schedules.

use anyhow::Result;

use crate::model::TaskTree;

use super::csc::CscMatrix;
use super::etree::{elimination_tree, postorder};

/// A supernode: a contiguous run of `width` columns (in the postordered
/// matrix) sharing the same below-diagonal structure.
#[derive(Debug, Clone)]
pub struct Supernode {
    /// First column of the supernode.
    pub first_col: usize,
    /// Number of columns eliminated by this supernode's task.
    pub width: usize,
    /// Row indices of the front (the supernode's columns plus the
    /// union of their below-panel structure), sorted ascending. The
    /// first `width` entries are the eliminated columns themselves.
    pub rows: Vec<usize>,
    /// Parent supernode index (self for roots).
    pub parent: usize,
}

impl Supernode {
    /// Front order `n` (rows of the dense frontal matrix).
    pub fn front_order(&self) -> usize {
        self.rows.len()
    }

    /// Flops of the partial factorization of this front
    /// (`potrf + trsm + schur`, cf. `python/compile/model.py`).
    pub fn flops(&self) -> f64 {
        let n = self.front_order() as f64;
        let k = self.width as f64;
        let m = n - k;
        k * k * k / 3.0 + m * k * k + m * m * k
    }
}

/// Result of the analysis phase.
#[derive(Debug, Clone)]
pub struct SymbolicFactorization {
    /// Permutation applied (`perm[new] = old`), including postorder.
    pub perm: Vec<usize>,
    /// Column elimination-tree parent (on the permuted matrix).
    pub etree: Vec<usize>,
    /// Pattern of each column of L (row indices >= column, sorted).
    pub l_pattern: Vec<Vec<usize>>,
    /// Supernode partition, in postorder (children before parents).
    pub supernodes: Vec<Supernode>,
    /// Supernode index of every column.
    pub col_to_snode: Vec<usize>,
    /// Relative indices for extend-add: `rel[c][a]` is the
    /// parent-front-local row of the `a`-th contribution row of
    /// supernode `c` (i.e. of `supernodes[c].rows[width + a]`). Empty
    /// for supernodes without a Schur complement (roots). Precomputed
    /// here so numeric assembly is pure integer-indexed scatter/add —
    /// no hashing on the hot path.
    pub rel: Vec<Vec<u32>>,
}

/// The assembly tree: the task tree the schedulers consume plus the
/// mapping back to supernodes.
#[derive(Debug, Clone)]
pub struct AssemblyTree {
    pub tree: TaskTree,
    pub symbolic: SymbolicFactorization,
}

/// Run the full analysis: permute by `perm` (fill-reducing), postorder
/// the elimination tree, compute L's pattern, form supernodes (merging
/// relaxed by `amalgamate` extra rows), and build the assembly tree.
pub fn analyze(a: &CscMatrix, perm: &[usize], amalgamate: usize) -> Result<AssemblyTree> {
    // 1. fill-reducing permutation
    let ap = a.permute_sym(perm)?;
    // 2. postorder the elimination tree and re-permute
    let parent = elimination_tree(&ap);
    let post = postorder(&parent);
    let ap = ap.permute_sym(&post)?;
    // compose: final perm[new] = perm[post[new]]
    let full_perm: Vec<usize> = post.iter().map(|&k| perm[k]).collect();
    let etree = elimination_tree(&ap);

    // 3. symbolic factorization: pattern of L column by column.
    // col j's pattern = A(j:, j) ∪ (children's patterns minus their
    // eliminated column), which is exact for Cholesky.
    let n = ap.n;
    let mut l_pattern: Vec<Vec<usize>> = Vec::with_capacity(n);
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    for j in 0..n {
        if etree[j] != j {
            children[etree[j]].push(j);
        }
    }
    let mut mark = vec![usize::MAX; n];
    for j in 0..n {
        let mut rows = vec![j];
        mark[j] = j;
        for i in ap.col_below_diag(j) {
            if mark[i] != j {
                mark[i] = j;
                rows.push(i);
            }
        }
        for &c in &children[j] {
            for &i in &l_pattern[c][1..] {
                // skip the child's eliminated column itself
                if i != j && mark[i] != j {
                    debug_assert!(i > j);
                    mark[i] = j;
                    rows.push(i);
                } else if i == j && mark[j] != j {
                    mark[j] = j;
                }
            }
        }
        rows.sort_unstable();
        l_pattern.push(rows);
    }

    // 4. fundamental supernodes: extend the current supernode while the
    // next column is the only child continuation with compatible
    // structure; relaxed amalgamation allows `amalgamate` extra rows.
    let mut col_to_snode = vec![usize::MAX; n];
    let mut snode_first: Vec<usize> = Vec::new();
    for j in 0..n {
        let fuse = j > 0 && {
            let prev = j - 1;
            // Fundamental supernodes: prev's etree parent is j, j has a
            // single child, and patterns nest exactly
            // (|L(:,prev)| == |L(:,j)| + 1). Relaxed amalgamation
            // (amalgamate > 0) also merges across multi-child columns
            // and tolerates up to `amalgamate` extra rows of padding —
            // the trade real multifrontal solvers make for larger
            // fronts (identity/zero padding keeps numerics exact).
            etree[prev] == j
                && (children[j].len() == 1 || amalgamate > 0)
                && l_pattern[prev].len() <= l_pattern[j].len() + 1 + amalgamate
        };
        if fuse {
            col_to_snode[j] = snode_first.len() - 1;
        } else {
            col_to_snode[j] = snode_first.len();
            snode_first.push(j);
        }
    }
    let num_snodes = snode_first.len();

    // 5. supernode rows (union over member columns = first column's
    // pattern extended by any amalgamation slack) and parents.
    let mut supernodes: Vec<Supernode> = Vec::with_capacity(num_snodes);
    for s in 0..num_snodes {
        let first = snode_first[s];
        let last = if s + 1 < num_snodes { snode_first[s + 1] } else { n };
        let width = last - first;
        // union of member patterns
        let mut rows: Vec<usize> = Vec::new();
        let mut mark2 = std::collections::HashSet::new();
        for j in first..last {
            for &i in &l_pattern[j] {
                if mark2.insert(i) {
                    rows.push(i);
                }
            }
        }
        rows.sort_unstable();
        // parent snode = snode of etree parent of last member column
        let p = etree[last - 1];
        let parent = if p == last - 1 { s } else { col_to_snode[p] };
        supernodes.push(Supernode { first_col: first, width, rows, parent });
    }

    // 6. assembly task tree (supernodes are already children-first).
    let parents: Vec<usize> = supernodes.iter().map(|s| s.parent).collect();
    let lens: Vec<f64> = supernodes.iter().map(|s| s.flops()).collect();
    // multifrontal forests: attach secondary roots under the last root
    let mut parents = parents;
    let roots: Vec<usize> = (0..num_snodes).filter(|&s| parents[s] == s).collect();
    if roots.len() > 1 {
        let main = *roots.last().unwrap();
        for &r in &roots {
            if r != main {
                parents[r] = main;
            }
        }
    }
    let tree = TaskTree::from_parents(&parents, &lens)?;

    // 7. relative indices: map each supernode's contribution rows into
    // its (tree-)parent's front-local positions by a two-pointer merge
    // over the sorted row lists. The assembly-tree invariant (a child's
    // contribution pattern is contained in the parent front) makes the
    // merge exact; the numeric layer consumes these for hash-free
    // extend-add.
    let mut rel: Vec<Vec<u32>> = vec![Vec::new(); num_snodes];
    for c in 0..num_snodes {
        let p = parents[c];
        if p == c {
            continue;
        }
        let csn = &supernodes[c];
        let crows = &csn.rows[csn.width..];
        if crows.is_empty() {
            continue;
        }
        let prows = &supernodes[p].rows;
        let mut out = Vec::with_capacity(crows.len());
        let mut j = 0usize;
        for &g in crows {
            while j < prows.len() && prows[j] < g {
                j += 1;
            }
            anyhow::ensure!(
                j < prows.len() && prows[j] == g,
                "contribution row {g} of supernode {c} missing from parent {p} front"
            );
            out.push(j as u32);
            j += 1;
        }
        rel[c] = out;
    }

    Ok(AssemblyTree {
        tree,
        symbolic: SymbolicFactorization {
            perm: full_perm,
            etree,
            l_pattern,
            supernodes,
            col_to_snode,
            rel,
        },
    })
}

/// Total factor nonzeros implied by the symbolic pattern.
pub fn factor_nnz(sym: &SymbolicFactorization) -> usize {
    sym.l_pattern.iter().map(|p| p.len()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, order};

    fn analyze_grid(k: usize, amalg: usize) -> AssemblyTree {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        analyze(&a, &perm, amalg).unwrap()
    }

    #[test]
    fn supernodes_partition_columns() {
        let at = analyze_grid(8, 0);
        let n = 64;
        let total: usize = at.symbolic.supernodes.iter().map(|s| s.width).sum();
        assert_eq!(total, n);
        // each column maps into its supernode's range
        for (j, &s) in at.symbolic.col_to_snode.iter().enumerate() {
            let sn = &at.symbolic.supernodes[s];
            assert!(sn.first_col <= j && j < sn.first_col + sn.width);
        }
    }

    #[test]
    fn front_rows_start_with_eliminated_columns() {
        let at = analyze_grid(8, 0);
        for sn in &at.symbolic.supernodes {
            for w in 0..sn.width {
                assert_eq!(sn.rows[w], sn.first_col + w, "supernode {sn:?}");
            }
        }
    }

    #[test]
    fn tree_is_valid_and_rooted() {
        let at = analyze_grid(10, 0);
        at.tree.validate().unwrap();
        assert_eq!(at.tree.len(), at.symbolic.supernodes.len());
    }

    #[test]
    fn l_pattern_contains_a_pattern() {
        // no cancellations: pattern of L ⊇ lower pattern of A
        let k = 6;
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = analyze(&a, &perm, 0).unwrap();
        let ap = a
            .permute_sym(&at.symbolic.perm)
            .unwrap();
        for j in 0..ap.n {
            for i in ap.col_below_diag(j) {
                assert!(
                    at.symbolic.l_pattern[j].contains(&i),
                    "A entry ({i},{j}) missing from L pattern"
                );
            }
        }
    }

    #[test]
    fn l_pattern_parent_containment() {
        // Cholesky structure theorem: L(:, j) \ {j} ⊆ L(:, parent(j))
        let at = analyze_grid(7, 0);
        let sym = &at.symbolic;
        for j in 0..sym.etree.len() {
            let p = sym.etree[j];
            if p == j {
                continue;
            }
            for &i in &sym.l_pattern[j][1..] {
                if i == p {
                    continue;
                }
                assert!(
                    sym.l_pattern[p].contains(&i),
                    "row {i} of col {j} missing in parent col {p}"
                );
            }
        }
    }

    #[test]
    fn amalgamation_reduces_task_count() {
        let none = analyze_grid(12, 0);
        let some = analyze_grid(12, 8);
        assert!(
            some.tree.len() < none.tree.len(),
            "amalg {} !< fundamental {}",
            some.tree.len(),
            none.tree.len()
        );
    }

    #[test]
    fn task_lengths_are_front_flops() {
        let at = analyze_grid(6, 0);
        for (i, sn) in at.symbolic.supernodes.iter().enumerate() {
            assert!((at.tree.nodes[i].len - sn.flops()).abs() < 1e-9);
            assert!(sn.flops() > 0.0);
        }
    }

    #[test]
    fn random_spd_with_rcm_analyzes() {
        let mut rng = crate::util::rng::Rng::new(31);
        let a = gen::random_spd(80, 4, &mut rng);
        let perm = order::reverse_cuthill_mckee(&a);
        let at = analyze(&a, &perm, 2).unwrap();
        at.tree.validate().unwrap();
        let total: usize = at.symbolic.supernodes.iter().map(|s| s.width).sum();
        assert_eq!(total, 80);
    }

    #[test]
    fn relative_indices_agree_with_row_search() {
        // rel[c][a] must be exactly the position of the child's a-th
        // contribution row inside the parent's sorted row list, for
        // fundamental and amalgamated trees alike
        for at in [analyze_grid(9, 0), analyze_grid(9, 4)] {
            let sym = &at.symbolic;
            assert_eq!(sym.rel.len(), sym.supernodes.len());
            for (s, node) in at.tree.nodes.iter().enumerate() {
                for &c in &node.children {
                    let c = c as usize;
                    let csn = &sym.supernodes[c];
                    let crows = &csn.rows[csn.width..];
                    assert_eq!(sym.rel[c].len(), crows.len());
                    let prows = &sym.supernodes[s].rows;
                    for (a, &g) in crows.iter().enumerate() {
                        let want = prows.binary_search(&g).unwrap();
                        assert_eq!(sym.rel[c][a] as usize, want, "snode {c} row {a}");
                    }
                }
            }
        }
    }

    #[test]
    fn roots_have_no_relative_indices() {
        let at = analyze_grid(8, 2);
        for (s, sn) in at.symbolic.supernodes.iter().enumerate() {
            if sn.width == sn.front_order() {
                assert!(at.symbolic.rel[s].is_empty());
            }
        }
    }

    #[test]
    fn factor_nnz_at_least_matrix_lower_nnz() {
        let k = 9;
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = analyze(&a, &perm, 0).unwrap();
        let lower_nnz = (0..a.n).map(|j| a.col_below_diag(j).count() + 1).sum::<usize>();
        assert!(factor_nnz(&at.symbolic) >= lower_nnz);
    }
}
