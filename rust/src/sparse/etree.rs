//! Elimination trees (Liu, "The role of elimination trees in sparse
//! factorization", 1990 — the paper's reference [3]).
//!
//! `parent[j]` is the first row index below `j` in column `j` of the
//! Cholesky factor `L`; computed in near-linear time with path
//! compression, without forming `L`.

use anyhow::{bail, Result};

use super::csc::CscMatrix;

/// Elimination tree of a symmetric matrix: `parent[j] == j` marks a
/// root (forests arise for reducible matrices).
pub fn elimination_tree(a: &CscMatrix) -> Vec<usize> {
    let n = a.n;
    let mut parent = vec![usize::MAX; n];
    let mut ancestor = vec![usize::MAX; n];
    for j in 0..n {
        for i in a.col_above_diag(j) {
            // walk from i up to the current root, compressing to j
            let mut r = i;
            while ancestor[r] != usize::MAX && ancestor[r] != j {
                let next = ancestor[r];
                ancestor[r] = j;
                r = next;
            }
            if ancestor[r] == usize::MAX {
                ancestor[r] = j;
                parent[r] = j;
            }
        }
    }
    // normalize roots to self-loops
    for j in 0..n {
        if parent[j] == usize::MAX {
            parent[j] = j;
        }
    }
    parent
}

/// Postorder of an elimination forest (children before parents,
/// iterative). Returns `post` with `post[k] = k`-th node in postorder.
pub fn postorder(parent: &[usize]) -> Vec<usize> {
    let n = parent.len();
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut roots = Vec::new();
    for j in 0..n {
        if parent[j] == j {
            roots.push(j);
        } else {
            children[parent[j]].push(j);
        }
    }
    let mut post = Vec::with_capacity(n);
    // two-phase iterative postorder
    let mut stack: Vec<(usize, bool)> = Vec::with_capacity(n);
    for &r in roots.iter().rev() {
        stack.push((r, false));
    }
    while let Some((v, expanded)) = stack.pop() {
        if expanded {
            post.push(v);
        } else {
            stack.push((v, true));
            for &c in children[v].iter().rev() {
                stack.push((c, false));
            }
        }
    }
    post
}

/// Check `post` is a valid postorder of `parent`.
pub fn is_postorder(parent: &[usize], post: &[usize]) -> bool {
    let n = parent.len();
    if post.len() != n {
        return false;
    }
    let mut pos = vec![usize::MAX; n];
    for (k, &v) in post.iter().enumerate() {
        if v >= n || pos[v] != usize::MAX {
            return false;
        }
        pos[v] = k;
    }
    (0..n).all(|j| parent[j] == j || pos[j] < pos[parent[j]])
}

/// Nonzero counts of each column of `L` (including the diagonal),
/// via row-subtree traversal (simple O(nnz(A) · height) bound — fine
/// for the problem sizes in this repo; see `symbolic` for the full
/// pattern).
pub fn col_counts(a: &CscMatrix, parent: &[usize]) -> Vec<usize> {
    let n = a.n;
    let mut count = vec![1usize; n]; // diagonal
    let mut mark = vec![usize::MAX; n];
    for i in 0..n {
        mark[i] = i;
        // row i of L: walk from each k (A_ik, k<i) up the etree until a
        // marked node; every unmarked node j on the way gains row i.
        for k in a.col_above_diag(i) {
            let mut j = k;
            while mark[j] != i {
                mark[j] = i;
                count[j] += 1;
                if parent[j] == j {
                    break;
                }
                j = parent[j];
                if j == i {
                    break;
                }
            }
        }
    }
    count
}

/// Validate that `parent` is a forest over `0..n` with edges pointing
/// to higher indices (elimination trees are topologically ordered).
pub fn validate_etree(parent: &[usize]) -> Result<()> {
    for (j, &p) in parent.iter().enumerate() {
        if p >= parent.len() {
            bail!("parent[{j}] = {p} out of range");
        }
        if p != j && p < j {
            bail!("etree edge {j} -> {p} goes downward");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    /// Arrowhead matrix: column 0 connected to all — etree is a chain.
    fn arrowhead(n: usize) -> CscMatrix {
        let mut t = vec![(0usize, 0usize, n as f64)];
        for i in 1..n {
            t.push((i, i, n as f64));
            t.push((i, 0, 1.0));
            t.push((0, i, 1.0));
        }
        CscMatrix::from_triplets(n, &t).unwrap()
    }

    #[test]
    fn arrowhead_etree_is_chain() {
        let a = arrowhead(6);
        let p = elimination_tree(&a);
        // fill-in makes every column j point to j+1
        assert_eq!(p, vec![1, 2, 3, 4, 5, 5]);
        validate_etree(&p).unwrap();
    }

    #[test]
    fn tridiagonal_etree_is_chain() {
        let n = 8;
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i + 1 < n {
                t.push((i + 1, i, -1.0));
                t.push((i, i + 1, -1.0));
            }
        }
        let a = CscMatrix::from_triplets(n, &t).unwrap();
        let p = elimination_tree(&a);
        for j in 0..n - 1 {
            assert_eq!(p[j], j + 1);
        }
        assert_eq!(p[n - 1], n - 1);
    }

    #[test]
    fn diagonal_matrix_is_forest_of_singletons() {
        let t: Vec<(usize, usize, f64)> = (0..5).map(|i| (i, i, 1.0)).collect();
        let a = CscMatrix::from_triplets(5, &t).unwrap();
        let p = elimination_tree(&a);
        assert_eq!(p, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn postorder_is_valid() {
        let a = gen::grid_laplacian_2d(6);
        let p = elimination_tree(&a);
        let post = postorder(&p);
        assert!(is_postorder(&p, &post));
    }

    #[test]
    fn postorder_handles_forest() {
        let parent = vec![0, 1, 0, 1]; // two roots 0,1 with children 2,3
        let post = postorder(&parent);
        assert!(is_postorder(&parent, &post));
        assert_eq!(post.len(), 4);
    }

    #[test]
    fn col_counts_tridiagonal() {
        // L of a tridiagonal SPD matrix is bidiagonal: counts = 2,…,2,1
        let n = 6;
        let mut t = Vec::new();
        for i in 0..n {
            t.push((i, i, 4.0));
            if i + 1 < n {
                t.push((i + 1, i, -1.0));
                t.push((i, i + 1, -1.0));
            }
        }
        let a = CscMatrix::from_triplets(n, &t).unwrap();
        let p = elimination_tree(&a);
        let c = col_counts(&a, &p);
        assert_eq!(c, vec![2, 2, 2, 2, 2, 1]);
    }

    #[test]
    fn col_counts_arrowhead_fillin() {
        // eliminating col 0 fills the whole trailing block: counts are
        // n, n-1, ..., 1
        let n = 5;
        let a = arrowhead(n);
        let p = elimination_tree(&a);
        let c = col_counts(&a, &p);
        assert_eq!(c, vec![5, 4, 3, 2, 1]);
    }

    #[test]
    fn grid_etree_root_is_last_column() {
        let a = gen::grid_laplacian_2d(5);
        let p = elimination_tree(&a);
        validate_etree(&p).unwrap();
        // connected matrix ⇒ single root = n-1
        let roots: Vec<usize> = (0..a.n).filter(|&j| p[j] == j).collect();
        assert_eq!(roots, vec![a.n - 1]);
    }
}
