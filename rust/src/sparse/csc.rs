//! Compressed-sparse-column square symmetric matrices.
//!
//! Both triangles are stored (simplifies traversal); constructors
//! enforce symmetry of the pattern. Row indices are sorted per column.

use anyhow::{bail, Result};

/// Square sparse matrix in CSC format.
#[derive(Debug, Clone)]
pub struct CscMatrix {
    pub n: usize,
    /// `colptr[j]..colptr[j+1]` indexes column `j`'s entries.
    pub colptr: Vec<usize>,
    /// Row index of each entry, sorted within a column.
    pub rowidx: Vec<usize>,
    /// Numeric values (same layout as `rowidx`).
    pub values: Vec<f64>,
}

impl CscMatrix {
    /// Build from unsorted triplets; duplicate entries are summed.
    pub fn from_triplets(n: usize, triplets: &[(usize, usize, f64)]) -> Result<Self> {
        for &(i, j, _) in triplets {
            if i >= n || j >= n {
                bail!("triplet ({i},{j}) out of range for n={n}");
            }
        }
        // bucket by column
        let mut per_col: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        for &(i, j, v) in triplets {
            per_col[j].push((i, v));
        }
        let mut colptr = Vec::with_capacity(n + 1);
        let mut rowidx = Vec::with_capacity(triplets.len());
        let mut values = Vec::with_capacity(triplets.len());
        colptr.push(0);
        for col in &mut per_col {
            col.sort_by_key(|&(i, _)| i);
            let mut last: Option<usize> = None;
            for &(i, v) in col.iter() {
                if last == Some(i) {
                    *values.last_mut().unwrap() += v;
                } else {
                    rowidx.push(i);
                    values.push(v);
                    last = Some(i);
                }
            }
            colptr.push(rowidx.len());
        }
        Ok(CscMatrix { n, colptr, rowidx, values })
    }

    /// Entries of column `j` as `(row, value)` pairs.
    pub fn col(&self, j: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let range = self.colptr[j]..self.colptr[j + 1];
        self.rowidx[range.clone()]
            .iter()
            .copied()
            .zip(self.values[range].iter().copied())
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.rowidx.len()
    }

    /// Value at `(i, j)` (binary search; 0.0 if absent).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let range = self.colptr[j]..self.colptr[j + 1];
        match self.rowidx[range.clone()].binary_search(&i) {
            Ok(k) => self.values[range.start + k],
            Err(_) => 0.0,
        }
    }

    /// Check that the sparsity pattern (and values, within `tol`) are
    /// symmetric.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        for j in 0..self.n {
            for (i, v) in self.col(j) {
                if (self.get(j, i) - v).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Symmetric permutation `B = P A Pᵀ`, with `perm[k] = old index of
    /// new index k` (i.e. `B[k,l] = A[perm[k], perm[l]]`).
    pub fn permute_sym(&self, perm: &[usize]) -> Result<CscMatrix> {
        if perm.len() != self.n {
            bail!("permutation length mismatch");
        }
        let mut inv = vec![usize::MAX; self.n];
        for (new, &old) in perm.iter().enumerate() {
            if old >= self.n || inv[old] != usize::MAX {
                bail!("invalid permutation");
            }
            inv[old] = new;
        }
        let mut triplets = Vec::with_capacity(self.nnz());
        for j in 0..self.n {
            for (i, v) in self.col(j) {
                triplets.push((inv[i], inv[j], v));
            }
        }
        CscMatrix::from_triplets(self.n, &triplets)
    }

    /// Dense row-major copy (tests / small problems only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0f64; self.n * self.n];
        for j in 0..self.n {
            for (i, v) in self.col(j) {
                d[i * self.n + j] = v;
            }
        }
        d
    }

    /// `y = A x` (for residual checks).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0f64; self.n];
        for j in 0..self.n {
            let xj = x[j];
            for (i, v) in self.col(j) {
                y[i] += v * xj;
            }
        }
        y
    }

    /// Strict-lower-triangle pattern of column `j` (rows > j).
    pub fn col_below_diag(&self, j: usize) -> impl Iterator<Item = usize> + '_ {
        let range = self.colptr[j]..self.colptr[j + 1];
        self.rowidx[range].iter().copied().filter(move |&i| i > j)
    }

    /// Upper-triangle pattern of column `j` (rows < j) — the row set
    /// Liu's elimination-tree algorithm consumes.
    pub fn col_above_diag(&self, j: usize) -> impl Iterator<Item = usize> + '_ {
        let range = self.colptr[j]..self.colptr[j + 1];
        self.rowidx[range].iter().copied().filter(move |&i| i < j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CscMatrix {
        // [[4,1,0],[1,4,2],[0,2,4]]
        CscMatrix::from_triplets(
            3,
            &[
                (0, 0, 4.0),
                (1, 0, 1.0),
                (0, 1, 1.0),
                (1, 1, 4.0),
                (2, 1, 2.0),
                (1, 2, 2.0),
                (2, 2, 4.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn triplets_round_trip() {
        let m = sample();
        assert_eq!(m.nnz(), 7);
        assert_eq!(m.get(0, 0), 4.0);
        assert_eq!(m.get(2, 1), 2.0);
        assert_eq!(m.get(0, 2), 0.0);
        assert!(m.is_symmetric(0.0));
    }

    #[test]
    fn duplicates_are_summed() {
        let m = CscMatrix::from_triplets(2, &[(0, 0, 1.0), (0, 0, 2.0)]).unwrap();
        assert_eq!(m.get(0, 0), 3.0);
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(CscMatrix::from_triplets(2, &[(2, 0, 1.0)]).is_err());
    }

    #[test]
    fn rows_sorted_within_column() {
        let m = CscMatrix::from_triplets(3, &[(2, 0, 1.0), (0, 0, 2.0), (1, 0, 3.0)]).unwrap();
        let rows: Vec<usize> = m.col(0).map(|(i, _)| i).collect();
        assert_eq!(rows, vec![0, 1, 2]);
    }

    #[test]
    fn permute_sym_round_trips() {
        let m = sample();
        let perm = vec![2, 0, 1];
        let pm = m.permute_sym(&perm).unwrap();
        // B[k,l] = A[perm[k], perm[l]]
        for k in 0..3 {
            for l in 0..3 {
                assert_eq!(pm.get(k, l), m.get(perm[k], perm[l]));
            }
        }
        assert!(pm.is_symmetric(0.0));
    }

    #[test]
    fn permute_rejects_bad() {
        let m = sample();
        assert!(m.permute_sym(&[0, 0, 1]).is_err());
        assert!(m.permute_sym(&[0, 1]).is_err());
    }

    #[test]
    fn matvec_matches_dense() {
        let m = sample();
        let x = vec![1.0, 2.0, 3.0];
        let y = m.matvec(&x);
        let d = m.to_dense();
        for i in 0..3 {
            let want: f64 = (0..3).map(|j| d[i * 3 + j] * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn triangle_iterators() {
        let m = sample();
        assert_eq!(m.col_below_diag(0).collect::<Vec<_>>(), vec![1]);
        assert_eq!(m.col_above_diag(1).collect::<Vec<_>>(), vec![0]);
        assert_eq!(m.col_above_diag(0).count(), 0);
    }
}
