//! Sparse problem generators.
//!
//! Stand-in for the University of Florida collection (repro substitution,
//! DESIGN.md §2): grid Laplacians are the canonical PDE matrices whose
//! assembly trees the multifrontal literature (and the paper's Figure
//! 13/14 dataset) is built on; the random SPD generator adds irregular
//! patterns.

use crate::util::rng::Rng;

use super::csc::CscMatrix;

/// 5-point 2D Laplacian on a `k x k` grid (n = k²), SPD.
pub fn grid_laplacian_2d(k: usize) -> CscMatrix {
    let n = k * k;
    let idx = |x: usize, y: usize| y * k + x;
    let mut t = Vec::with_capacity(5 * n);
    for y in 0..k {
        for x in 0..k {
            let c = idx(x, y);
            t.push((c, c, 4.0));
            if x + 1 < k {
                t.push((idx(x + 1, y), c, -1.0));
                t.push((c, idx(x + 1, y), -1.0));
            }
            if y + 1 < k {
                t.push((idx(x, y + 1), c, -1.0));
                t.push((c, idx(x, y + 1), -1.0));
            }
        }
    }
    CscMatrix::from_triplets(n, &t).unwrap()
}

/// 7-point 3D Laplacian on a `k x k x k` grid (n = k³), SPD.
pub fn grid_laplacian_3d(k: usize) -> CscMatrix {
    let n = k * k * k;
    let idx = |x: usize, y: usize, z: usize| (z * k + y) * k + x;
    let mut t = Vec::with_capacity(7 * n);
    for z in 0..k {
        for y in 0..k {
            for x in 0..k {
                let c = idx(x, y, z);
                t.push((c, c, 6.0));
                let mut nb = |o: usize| {
                    t.push((o, c, -1.0));
                    t.push((c, o, -1.0));
                };
                if x + 1 < k {
                    nb(idx(x + 1, y, z));
                }
                if y + 1 < k {
                    nb(idx(x, y + 1, z));
                }
                if z + 1 < k {
                    nb(idx(x, y, z + 1));
                }
            }
        }
    }
    CscMatrix::from_triplets(n, &t).unwrap()
}

/// Random sparse SPD matrix: symmetric pattern with ~`avg_deg`
/// off-diagonals per row, made diagonally dominant.
pub fn random_spd(n: usize, avg_deg: usize, rng: &mut Rng) -> CscMatrix {
    let mut t = Vec::with_capacity(n * (avg_deg + 1));
    let mut deg = vec![0f64; n];
    let m = n * avg_deg / 2;
    for _ in 0..m {
        let i = rng.below(n);
        let j = rng.below(n);
        if i == j {
            continue;
        }
        let v = -rng.range_f64(0.1, 1.0);
        t.push((i, j, v));
        t.push((j, i, v));
        deg[i] += v.abs();
        deg[j] += v.abs();
    }
    for i in 0..n {
        t.push((i, i, deg[i] + 1.0)); // strict diagonal dominance ⇒ SPD
    }
    CscMatrix::from_triplets(n, &t).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_2d_shape_and_symmetry() {
        let a = grid_laplacian_2d(4);
        assert_eq!(a.n, 16);
        assert!(a.is_symmetric(0.0));
        // interior node has 4 neighbors + diagonal
        let c = a.col(5).count();
        assert_eq!(c, 5);
        // corner has 2 neighbors
        assert_eq!(a.col(0).count(), 3);
    }

    #[test]
    fn grid_2d_row_sums_nonneg() {
        // Laplacian row sums are >= 0 (boundary rows positive)
        let a = grid_laplacian_2d(3);
        let ones = vec![1.0; a.n];
        let y = a.matvec(&ones);
        assert!(y.iter().all(|&v| v >= -1e-12));
        assert!(y.iter().any(|&v| v > 0.5));
    }

    #[test]
    fn grid_3d_shape() {
        let a = grid_laplacian_3d(3);
        assert_eq!(a.n, 27);
        assert!(a.is_symmetric(0.0));
        // center node (1,1,1) has 6 neighbors + diagonal
        let center = (1 * 3 + 1) * 3 + 1;
        assert_eq!(a.col(center).count(), 7);
    }

    #[test]
    fn random_spd_is_symmetric_and_dominant() {
        let mut rng = Rng::new(9);
        let a = random_spd(50, 4, &mut rng);
        assert!(a.is_symmetric(1e-12));
        // diagonal dominance
        for j in 0..a.n {
            let diag = a.get(j, j);
            let off: f64 = a.col(j).filter(|&(i, _)| i != j).map(|(_, v)| v.abs()).sum();
            assert!(diag > off, "col {j}: diag {diag} <= off {off}");
        }
    }

    #[test]
    fn random_spd_deterministic() {
        let a = random_spd(30, 3, &mut Rng::new(5));
        let b = random_spd(30, 3, &mut Rng::new(5));
        assert_eq!(a.rowidx, b.rowidx);
        assert_eq!(a.values, b.values);
    }
}
