//! Matrix Market coordinate format I/O (the UF collection's format).
//!
//! Supports `matrix coordinate real {general|symmetric}`; symmetric
//! files are expanded to both triangles on read.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::csc::CscMatrix;

/// Read a Matrix Market file into a [`CscMatrix`].
pub fn read_matrix_market(path: &Path) -> Result<CscMatrix> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    parse_matrix_market(std::io::BufReader::new(f))
}

/// Parse Matrix Market content from any reader.
pub fn parse_matrix_market<R: BufRead>(reader: R) -> Result<CscMatrix> {
    let mut lines = reader.lines();
    let header = lines
        .next()
        .context("empty file")??
        .to_lowercase();
    let fields: Vec<&str> = header.split_whitespace().collect();
    if fields.len() < 5 || fields[0] != "%%matrixmarket" || fields[1] != "matrix" {
        bail!("not a MatrixMarket matrix header: {header}");
    }
    if fields[2] != "coordinate" || fields[3] != "real" && fields[3] != "integer" {
        bail!("only coordinate real/integer supported, got {header}");
    }
    let symmetric = match fields[4] {
        "general" => false,
        "symmetric" => true,
        other => bail!("unsupported symmetry {other}"),
    };

    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('%') {
            continue;
        }
        size_line = Some(trimmed.to_string());
        break;
    }
    let size_line = size_line.context("missing size line")?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>())
        .collect::<Result<_, _>>()
        .with_context(|| format!("bad size line: {size_line}"))?;
    if dims.len() != 3 {
        bail!("size line needs 3 fields: {size_line}");
    }
    let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);
    if rows != cols {
        bail!("only square matrices supported ({rows}x{cols})");
    }

    let mut triplets = Vec::with_capacity(if symmetric { 2 * nnz } else { nnz });
    let mut seen = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it.next().context("bad entry line")?.parse()?;
        let j: usize = it.next().context("bad entry line")?.parse()?;
        let v: f64 = it.next().map(|s| s.parse()).transpose()?.unwrap_or(1.0);
        if i < 1 || j < 1 || i > rows || j > cols {
            bail!("entry ({i},{j}) out of bounds");
        }
        let (i, j) = (i - 1, j - 1);
        triplets.push((i, j, v));
        if symmetric && i != j {
            triplets.push((j, i, v));
        }
        seen += 1;
    }
    if seen != nnz {
        bail!("expected {nnz} entries, found {seen}");
    }
    CscMatrix::from_triplets(rows, &triplets)
}

/// Write `a` as `matrix coordinate real general`.
pub fn write_matrix_market(a: &CscMatrix, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by malltree")?;
    writeln!(w, "{} {} {}", a.n, a.n, a.nnz())?;
    for j in 0..a.n {
        for (i, v) in a.col(j) {
            writeln!(w, "{} {} {}", i + 1, j + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const SYM: &str = "%%MatrixMarket matrix coordinate real symmetric\n\
                       % a comment\n\
                       3 3 4\n\
                       1 1 4.0\n\
                       2 1 1.0\n\
                       2 2 4.0\n\
                       3 3 4.0\n";

    #[test]
    fn parses_symmetric_and_expands() {
        let a = parse_matrix_market(Cursor::new(SYM)).unwrap();
        assert_eq!(a.n, 3);
        assert_eq!(a.get(0, 1), 1.0); // expanded mirror
        assert_eq!(a.get(1, 0), 1.0);
        assert!(a.is_symmetric(0.0));
    }

    #[test]
    fn rejects_wrong_counts() {
        let bad = "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n";
        assert!(parse_matrix_market(Cursor::new(bad)).is_err());
    }

    #[test]
    fn rejects_non_square() {
        let bad = "%%MatrixMarket matrix coordinate real general\n2 3 1\n1 1 1.0\n";
        assert!(parse_matrix_market(Cursor::new(bad)).is_err());
    }

    #[test]
    fn rejects_bad_header() {
        assert!(parse_matrix_market(Cursor::new("hello\n")).is_err());
        let arr = "%%MatrixMarket matrix array real general\n";
        assert!(parse_matrix_market(Cursor::new(arr)).is_err());
    }

    #[test]
    fn write_read_round_trip() {
        let a = crate::sparse::gen::grid_laplacian_2d(4);
        let dir = std::env::temp_dir().join("malltree_mm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("grid.mtx");
        write_matrix_market(&a, &path).unwrap();
        let b = read_matrix_market(&path).unwrap();
        assert_eq!(a.n, b.n);
        assert_eq!(a.nnz(), b.nnz());
        for j in 0..a.n {
            for (i, v) in a.col(j) {
                assert_eq!(b.get(i, j), v);
            }
        }
    }

    #[test]
    fn one_based_bounds_checked() {
        let bad = "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n";
        assert!(parse_matrix_market(Cursor::new(bad)).is_err());
        let bad2 = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        assert!(parse_matrix_market(Cursor::new(bad2)).is_err());
    }
}
