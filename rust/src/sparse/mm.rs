//! Matrix Market coordinate format I/O (the UF collection's format).
//!
//! Supports `matrix coordinate real {general|symmetric}`; symmetric
//! files are expanded to both triangles on read.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::csc::CscMatrix;

/// Read a Matrix Market file into a [`CscMatrix`].
pub fn read_matrix_market(path: &Path) -> Result<CscMatrix> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    parse_matrix_market(std::io::BufReader::new(f))
}

/// Preallocation ceiling for the triplet buffer: a hostile size line
/// claiming `usize::MAX` nonzeros must not commit gigabytes before the
/// per-entry checks run. Real entries grow the vec past this honestly.
const PREALLOC_CAP: usize = 1 << 20;

/// Parse Matrix Market content from any reader.
///
/// Every error carries the 1-based line number it was detected on.
/// Duplicate `(i, j)` entries are accepted and **summed** — the
/// [`CscMatrix::from_triplets`] policy, matching the usual convention
/// for assembled FEM output. Symmetric files must store the lower
/// triangle only (the MM spec's storage rule); the strict upper
/// triangle is rejected, and the mirror is expanded on read.
pub fn parse_matrix_market<R: BufRead>(reader: R) -> Result<CscMatrix> {
    let mut lines = reader.lines().enumerate().map(|(k, l)| (k + 1, l));
    let (_, header) = lines.next().context("empty file")?;
    let header = header.context("line 1: unreadable (not UTF-8?)")?.to_lowercase();
    let fields: Vec<&str> = header.split_whitespace().collect();
    if fields.len() < 5 || fields[0] != "%%matrixmarket" || fields[1] != "matrix" {
        bail!("line 1: not a MatrixMarket matrix header: {header}");
    }
    if fields[2] != "coordinate" || fields[3] != "real" && fields[3] != "integer" {
        bail!("line 1: only coordinate real/integer supported, got {header}");
    }
    let symmetric = match fields[4] {
        "general" => false,
        "symmetric" => true,
        other => bail!("line 1: unsupported symmetry {other}"),
    };

    let mut size = None;
    for (ln, line) in lines.by_ref() {
        let line = line.with_context(|| format!("line {ln}: unreadable"))?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('%') {
            continue;
        }
        size = Some((ln, trimmed.to_string()));
        break;
    }
    let (size_ln, size_line) = size.context("missing size line")?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| t.parse::<usize>())
        .collect::<Result<_, _>>()
        .with_context(|| format!("line {size_ln}: bad size line: {size_line}"))?;
    if dims.len() != 3 {
        bail!("line {size_ln}: size line needs 3 fields: {size_line}");
    }
    let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);
    if rows != cols {
        bail!("line {size_ln}: only square matrices supported ({rows}x{cols})");
    }
    if rows.checked_mul(cols).is_none() {
        bail!("line {size_ln}: dimensions {rows}x{cols} overflow");
    }

    let want = if symmetric { nnz.saturating_mul(2) } else { nnz };
    let mut triplets = Vec::with_capacity(want.min(PREALLOC_CAP));
    let mut seen = 0usize;
    for (ln, line) in lines {
        let line = line.with_context(|| format!("line {ln}: unreadable"))?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        if seen == nnz {
            bail!("line {ln}: more than the declared {nnz} entries");
        }
        let toks: Vec<&str> = t.split_whitespace().collect();
        if toks.len() != 2 && toks.len() != 3 {
            bail!("line {ln}: entry needs `i j [value]`, got {} fields", toks.len());
        }
        let i: usize = toks[0]
            .parse()
            .with_context(|| format!("line {ln}: bad row index {:?}", toks[0]))?;
        let j: usize = toks[1]
            .parse()
            .with_context(|| format!("line {ln}: bad column index {:?}", toks[1]))?;
        // two-token entries are pattern-style: value defaults to 1
        let v: f64 = match toks.get(2) {
            Some(s) => s.parse().with_context(|| format!("line {ln}: bad value {s:?}"))?,
            None => 1.0,
        };
        if !v.is_finite() {
            bail!("line {ln}: non-finite value {v}");
        }
        if i < 1 || j < 1 || i > rows || j > cols {
            bail!("line {ln}: entry ({i},{j}) out of bounds for {rows}x{cols}");
        }
        if symmetric && i < j {
            bail!(
                "line {ln}: symmetric file stores upper-triangle entry ({i},{j}); \
                 the spec requires lower-triangle storage"
            );
        }
        let (i, j) = (i - 1, j - 1);
        triplets.push((i, j, v));
        if symmetric && i != j {
            triplets.push((j, i, v));
        }
        seen += 1;
    }
    if seen != nnz {
        bail!("expected {nnz} entries, found {seen}");
    }
    CscMatrix::from_triplets(rows, &triplets)
}

/// Write `a` as `matrix coordinate real general`.
pub fn write_matrix_market(a: &CscMatrix, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by malltree")?;
    writeln!(w, "{} {} {}", a.n, a.n, a.nnz())?;
    for j in 0..a.n {
        for (i, v) in a.col(j) {
            writeln!(w, "{} {} {}", i + 1, j + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const SYM: &str = "%%MatrixMarket matrix coordinate real symmetric\n\
                       % a comment\n\
                       3 3 4\n\
                       1 1 4.0\n\
                       2 1 1.0\n\
                       2 2 4.0\n\
                       3 3 4.0\n";

    #[test]
    fn parses_symmetric_and_expands() {
        let a = parse_matrix_market(Cursor::new(SYM)).unwrap();
        assert_eq!(a.n, 3);
        assert_eq!(a.get(0, 1), 1.0); // expanded mirror
        assert_eq!(a.get(1, 0), 1.0);
        assert!(a.is_symmetric(0.0));
    }

    #[test]
    fn rejects_wrong_counts() {
        let bad = "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n";
        assert!(parse_matrix_market(Cursor::new(bad)).is_err());
    }

    #[test]
    fn rejects_non_square() {
        let bad = "%%MatrixMarket matrix coordinate real general\n2 3 1\n1 1 1.0\n";
        assert!(parse_matrix_market(Cursor::new(bad)).is_err());
    }

    #[test]
    fn rejects_bad_header() {
        assert!(parse_matrix_market(Cursor::new("hello\n")).is_err());
        let arr = "%%MatrixMarket matrix array real general\n";
        assert!(parse_matrix_market(Cursor::new(arr)).is_err());
    }

    #[test]
    fn write_read_round_trip() {
        let a = crate::sparse::gen::grid_laplacian_2d(4);
        let dir = std::env::temp_dir().join("malltree_mm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("grid.mtx");
        write_matrix_market(&a, &path).unwrap();
        let b = read_matrix_market(&path).unwrap();
        assert_eq!(a.n, b.n);
        assert_eq!(a.nnz(), b.nnz());
        for j in 0..a.n {
            for (i, v) in a.col(j) {
                assert_eq!(b.get(i, j), v);
            }
        }
    }

    #[test]
    fn one_based_bounds_checked() {
        let bad = "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n";
        assert!(parse_matrix_market(Cursor::new(bad)).is_err());
        let bad2 = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        assert!(parse_matrix_market(Cursor::new(bad2)).is_err());
    }

    #[test]
    fn errors_carry_line_numbers() {
        let bad = "%%MatrixMarket matrix coordinate real general\n% c\n2 2 2\n1 1 1.0\n9 1 1.0\n";
        let err = parse_matrix_market(Cursor::new(bad)).unwrap_err();
        assert!(format!("{err:#}").contains("line 5"), "got: {err:#}");
        let bad_idx = "%%MatrixMarket matrix coordinate real general\n2 2 1\nx 1 1.0\n";
        let err = parse_matrix_market(Cursor::new(bad_idx)).unwrap_err();
        assert!(format!("{err:#}").contains("line 3"), "got: {err:#}");
    }

    #[test]
    fn rejects_malformed_entries() {
        // four tokens on an entry line
        let four = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 1.0 7\n";
        assert!(parse_matrix_market(Cursor::new(four)).is_err());
        // non-finite value
        let nan = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 nan\n";
        assert!(parse_matrix_market(Cursor::new(nan)).is_err());
        let inf = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 inf\n";
        assert!(parse_matrix_market(Cursor::new(inf)).is_err());
        // more entries than declared
        let extra = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 1.0\n2 2 1.0\n";
        let err = parse_matrix_market(Cursor::new(extra)).unwrap_err();
        assert!(format!("{err:#}").contains("more than"), "got: {err:#}");
        // index too large for usize
        let huge = "%%MatrixMarket matrix coordinate real general\n2 2 1\n99999999999999999999999 1 1.0\n";
        assert!(parse_matrix_market(Cursor::new(huge)).is_err());
    }

    #[test]
    fn symmetric_rejects_upper_triangle_storage() {
        let bad = "%%MatrixMarket matrix coordinate real symmetric\n2 2 1\n1 2 1.0\n";
        let err = parse_matrix_market(Cursor::new(bad)).unwrap_err();
        assert!(format!("{err:#}").contains("lower-triangle"), "got: {err:#}");
    }

    #[test]
    fn duplicate_entries_are_summed() {
        let dup = "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 2.0\n1 1 3.0\n2 2 1.0\n";
        let a = parse_matrix_market(Cursor::new(dup)).unwrap();
        assert_eq!(a.get(0, 0), 5.0);
        assert_eq!(a.get(1, 1), 1.0);
    }

    #[test]
    fn pattern_style_entries_default_to_one() {
        let pat = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1\n2 2\n";
        let a = parse_matrix_market(Cursor::new(pat)).unwrap();
        assert_eq!(a.get(0, 0), 1.0);
        assert_eq!(a.get(1, 1), 1.0);
    }

    #[test]
    fn huge_declared_nnz_does_not_preallocate() {
        // a lying size line: the parse must fail on the count check,
        // not OOM on Vec::with_capacity
        let lie = format!(
            "%%MatrixMarket matrix coordinate real symmetric\n3 3 {}\n1 1 1.0\n",
            usize::MAX
        );
        assert!(parse_matrix_market(Cursor::new(lie)).is_err());
    }

    #[test]
    fn fuzz_mutated_bytes_never_panic() {
        use crate::util::prop;
        // Seed corpus: a valid symmetric file. Mutations overwrite,
        // insert (including bytes >= 0x80 → invalid UTF-8, which
        // BufRead::lines surfaces as an io::Error), and truncate; the
        // property is that parsing always returns Ok/Err — no panic,
        // no abort from oversized preallocation.
        let base = SYM.as_bytes();
        prop::check(
            prop::Config { cases: 300, seed: 0x4D4D_2026 },
            "mm_parse_total_on_mutated_bytes",
            |r| {
                let mut buf = base.to_vec();
                for _ in 0..=r.below(4) {
                    match r.below(3) {
                        0 => {
                            let p = r.below(buf.len());
                            buf[p] = (r.next_u64() & 0xFF) as u8;
                        }
                        1 => {
                            let p = r.below(buf.len() + 1);
                            buf.insert(p, (r.next_u64() & 0xFF) as u8);
                        }
                        _ => {
                            buf.truncate(r.below(buf.len()));
                            buf.push(b'\n');
                        }
                    }
                }
                buf
            },
            |buf| {
                let _ = parse_matrix_market(Cursor::new(buf));
                Ok(())
            },
        );
    }
}
