//! Sparse linear algebra substrate (the paper's application domain).
//!
//! The paper's malleable task trees are *assembly trees* of multifrontal
//! sparse Cholesky factorization. This module provides everything needed
//! to produce such trees from actual sparse matrices, built from
//! scratch:
//!
//! * [`csc`] — compressed sparse column symmetric matrices;
//! * [`mm`] — Matrix Market coordinate I/O;
//! * [`gen`] — problem generators (2D/3D grid Laplacians, random SPD)
//!   standing in for the University of Florida collection (DESIGN.md
//!   §2 substitution table);
//! * [`order`] — fill-reducing orderings (grid nested dissection,
//!   reverse Cuthill–McKee fallback);
//! * [`etree`] — Liu's elimination-tree algorithm, postorder, column
//!   counts;
//! * [`symbolic`] — symbolic factorization, fundamental supernodes,
//!   amalgamation, and extraction of the weighted assembly [`crate::model::TaskTree`].

pub mod csc;
pub mod etree;
pub mod gen;
pub mod mm;
pub mod order;
pub mod symbolic;

pub use csc::CscMatrix;
pub use etree::{elimination_tree, postorder};
pub use symbolic::{AssemblyTree, Supernode, SymbolicFactorization};
