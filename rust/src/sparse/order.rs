//! Fill-reducing orderings.
//!
//! * [`nested_dissection_2d`]/[`_3d`] — exact geometric nested
//!   dissection for the grid generators (the ordering that gives the
//!   classic well-balanced assembly trees the paper's dataset exhibits);
//! * [`reverse_cuthill_mckee`] — a pattern-only fallback for matrices
//!   without geometry (random SPD, Matrix Market imports).
//!
//! All functions return `perm` with `perm[new] = old`.

use std::collections::VecDeque;

use super::csc::CscMatrix;

/// Geometric nested dissection on a `k x k` grid. Recursively orders
/// each half before its separator line, so separators (future big
/// fronts) are eliminated last.
pub fn nested_dissection_2d(k: usize) -> Vec<usize> {
    let mut perm = Vec::with_capacity(k * k);
    // Work queue of sub-rectangles (x0, y0, w, h); explicit stack with
    // post-separator emission order handled by recursion-free DFS.
    nd2_rec(0, 0, k, k, k, &mut perm);
    perm
}

fn nd2_rec(x0: usize, y0: usize, w: usize, h: usize, k: usize, out: &mut Vec<usize>) {
    const LEAF: usize = 3;
    if w == 0 || h == 0 {
        return;
    }
    if w <= LEAF && h <= LEAF {
        for y in y0..y0 + h {
            for x in x0..x0 + w {
                out.push(y * k + x);
            }
        }
        return;
    }
    if w >= h {
        // vertical separator at column x0 + w/2
        let sx = x0 + w / 2;
        nd2_rec(x0, y0, sx - x0, h, k, out);
        nd2_rec(sx + 1, y0, x0 + w - sx - 1, h, k, out);
        for y in y0..y0 + h {
            out.push(y * k + sx);
        }
    } else {
        let sy = y0 + h / 2;
        nd2_rec(x0, y0, w, sy - y0, k, out);
        nd2_rec(x0, sy + 1, w, y0 + h - sy - 1, k, out);
        for x in x0..x0 + w {
            out.push(sy * k + x);
        }
    }
}

/// Geometric nested dissection on a `k x k x k` grid.
pub fn nested_dissection_3d(k: usize) -> Vec<usize> {
    let mut perm = Vec::with_capacity(k * k * k);
    nd3_rec([0, 0, 0], [k, k, k], k, &mut perm);
    perm
}

fn nd3_rec(o: [usize; 3], d: [usize; 3], k: usize, out: &mut Vec<usize>) {
    const LEAF: usize = 3;
    if d.iter().any(|&x| x == 0) {
        return;
    }
    if d.iter().all(|&x| x <= LEAF) {
        for z in o[2]..o[2] + d[2] {
            for y in o[1]..o[1] + d[1] {
                for x in o[0]..o[0] + d[0] {
                    out.push((z * k + y) * k + x);
                }
            }
        }
        return;
    }
    // split along the longest axis
    let axis = (0..3).max_by_key(|&a| d[a]).unwrap();
    let s = o[axis] + d[axis] / 2;
    let (o1, mut d1) = (o, d);
    d1[axis] = s - o[axis];
    let (mut o2, mut d2) = (o, d);
    o2[axis] = s + 1;
    d2[axis] = o[axis] + d[axis] - s - 1;
    nd3_rec(o1, d1, k, out);
    nd3_rec(o2, d2, k, out);
    // separator plane
    let (mut so, mut sd) = (o, d);
    so[axis] = s;
    sd[axis] = 1;
    for z in so[2]..so[2] + sd[2] {
        for y in so[1]..so[1] + sd[1] {
            for x in so[0]..so[0] + sd[0] {
                out.push((z * k + y) * k + x);
            }
        }
    }
}

/// Reverse Cuthill–McKee: BFS from a pseudo-peripheral vertex, reversed.
/// Bandwidth-reducing; a serviceable general-purpose fallback.
pub fn reverse_cuthill_mckee(a: &CscMatrix) -> Vec<usize> {
    let n = a.n;
    let deg: Vec<usize> = (0..n).map(|j| a.col(j).count()).collect();
    let mut visited = vec![false; n];
    let mut order = Vec::with_capacity(n);
    let mut queue = VecDeque::new();
    loop {
        // next unvisited vertex with minimum degree (component seed)
        let Some(seed) = (0..n)
            .filter(|&j| !visited[j])
            .min_by_key(|&j| deg[j])
        else {
            break;
        };
        visited[seed] = true;
        queue.push_back(seed);
        while let Some(v) = queue.pop_front() {
            order.push(v);
            let mut nbrs: Vec<usize> = a
                .col(v)
                .map(|(i, _)| i)
                .filter(|&i| i != v && !visited[i])
                .collect();
            nbrs.sort_by_key(|&i| deg[i]);
            for i in nbrs {
                visited[i] = true;
                queue.push_back(i);
            }
        }
    }
    order.reverse();
    order
}

/// Identity ordering (for comparisons).
pub fn natural(n: usize) -> Vec<usize> {
    (0..n).collect()
}

#[cfg(test)]
fn is_permutation(perm: &[usize]) -> bool {
    let mut seen = vec![false; perm.len()];
    for &p in perm {
        if p >= perm.len() || seen[p] {
            return false;
        }
        seen[p] = true;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{elimination_tree, etree::col_counts, gen};

    #[test]
    fn nd2_is_permutation() {
        for k in [2, 3, 5, 8, 16] {
            let p = nested_dissection_2d(k);
            assert_eq!(p.len(), k * k);
            assert!(is_permutation(&p), "k={k}");
        }
    }

    #[test]
    fn nd3_is_permutation() {
        for k in [2, 3, 4, 6] {
            let p = nested_dissection_3d(k);
            assert_eq!(p.len(), k * k * k);
            assert!(is_permutation(&p), "k={k}");
        }
    }

    #[test]
    fn rcm_is_permutation_and_handles_components() {
        // two disconnected triangles
        let t = vec![
            (0, 1, 1.0),
            (1, 0, 1.0),
            (1, 2, 1.0),
            (2, 1, 1.0),
            (3, 4, 1.0),
            (4, 3, 1.0),
            (4, 5, 1.0),
            (5, 4, 1.0),
            (0, 0, 2.0),
            (1, 1, 2.0),
            (2, 2, 2.0),
            (3, 3, 2.0),
            (4, 4, 2.0),
            (5, 5, 2.0),
        ];
        let a = CscMatrix::from_triplets(6, &t).unwrap();
        let p = reverse_cuthill_mckee(&a);
        assert!(is_permutation(&p));
    }

    #[test]
    fn nd_reduces_fill_vs_natural() {
        let k = 12;
        let a = gen::grid_laplacian_2d(k);
        let fill = |m: &CscMatrix| -> usize {
            let par = elimination_tree(m);
            col_counts(m, &par).iter().sum()
        };
        let natural_fill = fill(&a);
        let nd = a.permute_sym(&nested_dissection_2d(k)).unwrap();
        let nd_fill = fill(&nd);
        assert!(
            nd_fill < natural_fill,
            "nd fill {nd_fill} >= natural fill {natural_fill}"
        );
    }

    #[test]
    fn last_ordered_vertex_is_separator_member() {
        // the top-level separator is eliminated last
        let k = 8;
        let p = nested_dissection_2d(k);
        let last = p[k * k - 1];
        let (x, _y) = (last % k, last / k);
        assert_eq!(x, k / 2); // vertical separator column for w >= h
    }
}
