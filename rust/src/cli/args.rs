//! Minimal argument parser: positionals + `--flag [value]` options.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed argument bag.
#[derive(Debug, Clone)]
pub struct Args {
    positionals: std::collections::VecDeque<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Split `argv` into positionals, `--key value` options and bare
    /// `--flag`s (an option is a flag when the next token starts with
    /// `--` or is absent).
    pub fn new(argv: Vec<String>) -> Args {
        let mut positionals = std::collections::VecDeque::new();
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        options.insert(key.to_string(), it.next().unwrap());
                    }
                    _ => flags.push(key.to_string()),
                }
            } else if let Some(key) = tok.strip_prefix('-') {
                if !key.is_empty() && key.chars().all(|c| c.is_ascii_alphabetic()) {
                    match it.peek() {
                        Some(next) if !next.starts_with('-') => {
                            options.insert(key.to_string(), it.next().unwrap());
                        }
                        _ => flags.push(key.to_string()),
                    }
                } else {
                    positionals.push_back(tok);
                }
            } else {
                positionals.push_back(tok);
            }
        }
        Args { positionals, options, flags }
    }

    pub fn next_positional(&mut self) -> Option<String> {
        self.positionals.pop_front()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not a number")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not an integer")),
        }
    }

    /// Numeric option that must be finite and strictly positive
    /// (rejects NaN, ±inf, zero and negatives with the offending value).
    pub fn get_f64_positive(&self, name: &str, default: f64) -> Result<f64> {
        let v = self.get_f64(name, default)?;
        if !v.is_finite() || v <= 0.0 {
            bail!("--{name} must be a finite number > 0 (got {v})");
        }
        Ok(v)
    }

    /// Numeric option that must be finite and non-negative (fault
    /// fractions, cap ratios of zero are meaningful).
    pub fn get_f64_nonneg(&self, name: &str, default: f64) -> Result<f64> {
        let v = self.get_f64(name, default)?;
        if !v.is_finite() || v < 0.0 {
            bail!("--{name} must be a finite number >= 0 (got {v})");
        }
        Ok(v)
    }

    /// A malleability exponent: must lie in `(0, 1]` (the `p^α` model
    /// is only concave there).
    pub fn get_alpha(&self, name: &str, default: f64) -> Result<f64> {
        let v = self.get_f64(name, default)?;
        if !(v > 0.0 && v <= 1.0) {
            bail!("--{name} must be in (0, 1], the malleable speedup exponent (got {v})");
        }
        Ok(v)
    }

    /// A positive usize option (`0` is rejected with a pointer at the
    /// flag, e.g. core or node counts).
    pub fn get_usize_positive(&self, name: &str, default: usize) -> Result<usize> {
        let v = self.get_usize(name, default)?;
        if v == 0 {
            bail!("--{name} must be >= 1");
        }
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::new(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn parses_mixture() {
        let mut a = args("simulate --alpha 0.9 -p 40 --pjrt --trees 10");
        assert_eq!(a.next_positional().as_deref(), Some("simulate"));
        assert_eq!(a.get_f64("alpha", 1.0).unwrap(), 0.9);
        assert_eq!(a.get_usize("p", 1).unwrap(), 40);
        assert_eq!(a.get_usize("trees", 0).unwrap(), 10);
        assert!(a.has_flag("pjrt"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = args("cmd --verbose");
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn negative_numbers_are_positionals() {
        let mut a = args("cmd -5");
        assert_eq!(a.next_positional().as_deref(), Some("cmd"));
        assert_eq!(a.next_positional().as_deref(), Some("-5"));
    }

    #[test]
    fn bad_number_errors() {
        let a = args("cmd --alpha banana");
        assert!(a.get_f64("alpha", 1.0).is_err());
    }

    #[test]
    fn alpha_getter_enforces_the_unit_interval() {
        for bad in ["NaN", "0", "-0.5", "1.5", "inf"] {
            let a = args(&format!("cmd --alpha {bad}"));
            assert!(a.get_alpha("alpha", 0.9).is_err(), "accepted --alpha {bad}");
        }
        assert_eq!(args("cmd --alpha 1.0").get_alpha("alpha", 0.9).unwrap(), 1.0);
        assert_eq!(args("cmd").get_alpha("alpha", 0.9).unwrap(), 0.9);
    }

    #[test]
    fn positive_getter_rejects_nan_zero_negative_and_infinite() {
        for bad in ["NaN", "0", "-2", "inf", "-inf"] {
            let a = args(&format!("cmd --cap-ratio {bad}"));
            assert!(
                a.get_f64_positive("cap-ratio", 1.0).is_err(),
                "accepted --cap-ratio {bad}"
            );
        }
        assert_eq!(args("cmd --cap-ratio 0.4").get_f64_positive("cap-ratio", 1.0).unwrap(), 0.4);
    }

    #[test]
    fn nonneg_getter_allows_zero_but_not_nan_or_negative() {
        assert_eq!(args("cmd --frac 0").get_f64_nonneg("frac", 0.1).unwrap(), 0.0);
        for bad in ["NaN", "-0.1", "inf"] {
            let a = args(&format!("cmd --frac {bad}"));
            assert!(a.get_f64_nonneg("frac", 0.1).is_err(), "accepted --frac {bad}");
        }
    }

    #[test]
    fn positive_usize_getter_rejects_zero() {
        assert!(args("cmd --nodes 0").get_usize_positive("nodes", 4).is_err());
        assert_eq!(args("cmd --nodes 3").get_usize_positive("nodes", 4).unwrap(), 3);
    }
}
