//! Minimal argument parser: positionals + `--flag [value]` options.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

/// Parsed argument bag.
#[derive(Debug, Clone)]
pub struct Args {
    positionals: std::collections::VecDeque<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Split `argv` into positionals, `--key value` options and bare
    /// `--flag`s (an option is a flag when the next token starts with
    /// `--` or is absent).
    pub fn new(argv: Vec<String>) -> Args {
        let mut positionals = std::collections::VecDeque::new();
        let mut options = BTreeMap::new();
        let mut flags = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        options.insert(key.to_string(), it.next().unwrap());
                    }
                    _ => flags.push(key.to_string()),
                }
            } else if let Some(key) = tok.strip_prefix('-') {
                if !key.is_empty() && key.chars().all(|c| c.is_ascii_alphabetic()) {
                    match it.peek() {
                        Some(next) if !next.starts_with('-') => {
                            options.insert(key.to_string(), it.next().unwrap());
                        }
                        _ => flags.push(key.to_string()),
                    }
                } else {
                    positionals.push_back(tok);
                }
            } else {
                positionals.push_back(tok);
            }
        }
        Args { positionals, options, flags }
    }

    pub fn next_positional(&mut self) -> Option<String> {
        self.positionals.pop_front()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not a number")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} {v}: not an integer")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::new(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn parses_mixture() {
        let mut a = args("simulate --alpha 0.9 -p 40 --pjrt --trees 10");
        assert_eq!(a.next_positional().as_deref(), Some("simulate"));
        assert_eq!(a.get_f64("alpha", 1.0).unwrap(), 0.9);
        assert_eq!(a.get_usize("p", 1).unwrap(), 40);
        assert_eq!(a.get_usize("trees", 0).unwrap(), 10);
        assert!(a.has_flag("pjrt"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = args("cmd --verbose");
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn negative_numbers_are_positionals() {
        let mut a = args("cmd -5");
        assert_eq!(a.next_positional().as_deref(), Some("cmd"));
        assert_eq!(a.next_positional().as_deref(), Some("-5"));
    }

    #[test]
    fn bad_number_errors() {
        let a = args("cmd --alpha banana");
        assert!(a.get_f64("alpha", 1.0).is_err());
    }
}
