//! CLI subcommand implementations.

use anyhow::{bail, Context, Result};

use crate::config::Strategy;
use crate::metrics::{fit_alpha, BoxplotRow, Table};
use crate::model::{SpGraph, TaskTree};
use crate::sched::{
    agreg, divisible::divisible_makespan_sp, pm::PmSolution, proportional_makespan,
    relative_distances, PmSchedule, Profile,
};
use crate::sim::kerneldag::{timing_curve, KernelDag, MachineModel};
use crate::sparse::{gen, order, symbolic, AssemblyTree, CscMatrix};
use crate::util::rng::Rng;
use crate::workload::{dataset as gen_dataset, DatasetSpec};
use crate::DEFAULT_ALPHA;

use super::args::Args;

/// Load the problem selected by `--grid2d K | --grid3d K | --mtx F`.
fn load_problem(args: &Args) -> Result<(String, CscMatrix, Vec<usize>)> {
    if let Some(k) = args.get("grid2d") {
        let k: usize = k.parse().context("--grid2d K")?;
        return Ok((
            format!("grid2d_{k}"),
            gen::grid_laplacian_2d(k),
            order::nested_dissection_2d(k),
        ));
    }
    if let Some(k) = args.get("grid3d") {
        let k: usize = k.parse().context("--grid3d K")?;
        return Ok((
            format!("grid3d_{k}"),
            gen::grid_laplacian_3d(k),
            order::nested_dissection_3d(k),
        ));
    }
    // --matrix is an alias for --mtx (the corpus bench and docs use it)
    if let Some(path) = args.get("mtx").or_else(|| args.get("matrix")) {
        let a = crate::sparse::mm::read_matrix_market(std::path::Path::new(path))?;
        let perm = order::reverse_cuthill_mckee(&a);
        return Ok((path.to_string(), a, perm));
    }
    bail!("select a problem: --grid2d K | --grid3d K | --mtx FILE (--matrix works too)");
}

fn load_tree(args: &Args) -> Result<(String, TaskTree)> {
    if let Some(path) = args.get("tree") {
        let t = crate::workload::read_tree(std::path::Path::new(path))?;
        return Ok((path.to_string(), t));
    }
    let (name, a, perm) = load_problem(args)?;
    let amalg = args.get_usize("amalgamate", 4)?;
    let at = symbolic::analyze(&a, &perm, amalg)?;
    Ok((name, at.tree))
}

/// Tree plus per-task memory weights: exact symbolic weights for
/// generated/real problems, trace-carried weights for v2 trace files,
/// and the synthetic family for v1 traces.
fn load_tree_mem(args: &Args) -> Result<(String, TaskTree, crate::mem::MemWeights, &'static str)> {
    if let Some(path) = args.get("tree") {
        let (t, mem) = crate::workload::read_tree_mem(std::path::Path::new(path))?;
        return Ok(match mem {
            Some(w) => (path.to_string(), t, w, "trace (v2)"),
            None => {
                let seed = args.get_usize("seed", 0xDA7A)? as u64;
                let mut rng = Rng::new(seed);
                let w = crate::workload::synthetic_mem_weights(&t, &mut rng);
                (path.to_string(), t, w, "synthetic")
            }
        });
    }
    let (name, a, perm) = load_problem(args)?;
    let amalg = args.get_usize("amalgamate", 4)?;
    let at = symbolic::analyze(&a, &perm, amalg)?;
    let w = crate::mem::MemWeights::from_symbolic(&at);
    Ok((name, at.tree, w, "symbolic"))
}

/// Parse a `--profile d:p[,d:p...]` step-profile spec (durations and
/// processor counts; the last step persists forever).
fn parse_profile(spec: &str) -> Result<Profile> {
    let steps = spec
        .split(',')
        .map(|tok| {
            let (d, p) = tok
                .split_once(':')
                .with_context(|| format!("--profile {spec}: step {tok:?} is not d:p"))?;
            Ok((
                d.trim()
                    .parse::<f64>()
                    .with_context(|| format!("--profile {spec}: bad duration {d:?}"))?,
                p.trim()
                    .parse::<f64>()
                    .with_context(|| format!("--profile {spec}: bad processors {p:?}"))?,
            ))
        })
        .collect::<Result<Vec<(f64, f64)>>>()?;
    Profile::steps(&steps)
}

/// Parse a `--faults` / `--link-faults` disturbance spec:
/// comma-separated `crash:N@F`, `leave:N:C@F`, `join:N:C@F`,
/// `slow:N:X:D@F`, `linkslow:A:B:X:D@F`, `linkdown:A:B:D@F` items.
/// Event times `F` (and slowdown / link-fault durations `D`) are
/// *fractions of the fault-free makespan* — materialized per tree by
/// [`materialize_faults`] so one spec stresses trees of any size at
/// comparable points of their run.
fn parse_fault_spec(spec: &str) -> Result<Vec<(f64, crate::model::FaultKind)>> {
    use crate::model::FaultKind;
    let mut out = Vec::new();
    for item in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let item = item.trim();
        let (head, frac) = item
            .rsplit_once('@')
            .with_context(|| format!("--faults {item:?}: missing @FRACTION"))?;
        let frac: f64 = frac
            .parse()
            .with_context(|| format!("--faults {item:?}: bad fraction {frac:?}"))?;
        if !frac.is_finite() || frac < 0.0 {
            bail!("--faults {item:?}: fraction must be finite and >= 0");
        }
        let node = |v: &str| -> Result<usize> {
            v.parse()
                .with_context(|| format!("--faults {item:?}: bad node {v:?}"))
        };
        let num = |what: &str, v: &str| -> Result<f64> {
            v.parse()
                .with_context(|| format!("--faults {item:?}: bad {what} {v:?}"))
        };
        let toks: Vec<&str> = head.split(':').collect();
        let kind = match toks.as_slice() {
            ["crash", n] => FaultKind::Crash { node: node(n)? },
            ["leave", n, c] => FaultKind::Leave { node: node(n)?, cores: num("cores", c)? },
            ["join", n, c] => FaultKind::Join { node: node(n)?, cores: num("cores", c)? },
            ["slow", n, x, d] => FaultKind::Slowdown {
                node: node(n)?,
                factor: num("factor", x)?,
                duration: num("duration", d)?,
            },
            ["linkslow", a, b, x, d] => FaultKind::LinkDegrade {
                a: node(a)?,
                b: node(b)?,
                factor: num("factor", x)?,
                duration: num("duration", d)?,
            },
            ["linkdown", a, b, d] => FaultKind::LinkDown {
                a: node(a)?,
                b: node(b)?,
                duration: num("duration", d)?,
            },
            _ => bail!(
                "--faults {item:?}: want crash:N@F, leave:N:C@F, join:N:C@F, slow:N:X:D@F, \
                 linkslow:A:B:X:D@F or linkdown:A:B:D@F"
            ),
        };
        out.push((frac, kind));
    }
    if out.is_empty() {
        bail!("--faults {spec:?}: empty spec");
    }
    Ok(out)
}

/// Parse a `--net LAT:BW` uniform-network spec: inter-node latency
/// (seconds, finite and >= 0) and bandwidth (words per second, > 0;
/// `inf` models free links).
fn parse_net_spec(spec: &str, n_nodes: usize) -> Result<crate::net::NetModel> {
    let (lat, bw) = spec
        .split_once(':')
        .with_context(|| format!("--net {spec:?}: want LAT:BW"))?;
    let lat: f64 = lat
        .trim()
        .parse()
        .with_context(|| format!("--net {spec}: bad latency {lat:?}"))?;
    let bw = bw.trim();
    let bw: f64 = if bw.eq_ignore_ascii_case("inf") {
        f64::INFINITY
    } else {
        bw.parse()
            .with_context(|| format!("--net {spec}: bad bandwidth {bw:?}"))?
    };
    let net = crate::net::NetModel::uniform(n_nodes, lat, bw);
    net.validate().with_context(|| format!("--net {spec}"))?;
    Ok(net)
}

/// Scale a parsed fault-spec template to one tree's fault-free
/// makespan (slowdown and link-fault durations scale too).
fn materialize_faults(
    template: &[(f64, crate::model::FaultKind)],
    mff: f64,
) -> crate::model::FaultTrace {
    use crate::model::{FaultEvent, FaultKind, FaultTrace};
    FaultTrace::new(
        template
            .iter()
            .map(|&(frac, kind)| FaultEvent {
                time: frac * mff,
                kind: match kind {
                    FaultKind::Slowdown { node, factor, duration } => {
                        FaultKind::Slowdown { node, factor, duration: duration * mff }
                    }
                    FaultKind::LinkDegrade { a, b, factor, duration } => {
                        FaultKind::LinkDegrade { a, b, factor, duration: duration * mff }
                    }
                    FaultKind::LinkDown { a, b, duration } => {
                        FaultKind::LinkDown { a, b, duration: duration * mff }
                    }
                    k => k,
                },
            })
            .collect(),
    )
}

pub fn analyze(args: &mut Args) -> Result<()> {
    let (name, a, perm) = load_problem(args)?;
    let amalg = args.get_usize("amalgamate", 4)?;
    let at = symbolic::analyze(&a, &perm, amalg)?;
    let t = &at.tree;
    println!("problem {name}: n={} nnz={}", a.n, a.nnz());
    println!(
        "assembly tree: {} tasks, height {}, leaves {}, total flops {:.3e}, critical path {:.3e}",
        t.len(),
        t.height(),
        t.num_leaves(),
        t.total_work(),
        t.critical_path()
    );
    let max_front = at
        .symbolic
        .supernodes
        .iter()
        .map(|s| s.front_order())
        .max()
        .unwrap_or(0);
    println!(
        "supernodes: {}, widest front {max_front}, factor nnz {}",
        at.symbolic.supernodes.len(),
        symbolic::factor_nnz(&at.symbolic)
    );
    Ok(())
}

pub fn schedule(args: &mut Args) -> Result<()> {
    let (name, tree) = load_tree(args)?;
    let alpha = args.get_alpha("alpha", DEFAULT_ALPHA)?;
    let p = args.get_f64_positive("p", 40.0)?;
    let g = SpGraph::from_tree(&tree);
    let (ag, stats) = agreg(&g, alpha, p);
    let pm = PmSolution::solve(&ag, alpha).makespan_const(p);
    let prop = proportional_makespan(&ag, alpha, p);
    let div = divisible_makespan_sp(&ag, alpha, p);
    println!("tree {name}: {} tasks, alpha={alpha}, p={p}", tree.len());
    println!(
        "agreg: {} iterations, {} branches serialized",
        stats.iterations, stats.moved
    );
    let mut table = Table::new(&["strategy", "makespan", "vs PM"]);
    for (s, m) in [("PM", pm), ("Proportional", prop), ("Divisible", div)] {
        table.row(&[
            s.to_string(),
            format!("{m:.6e}"),
            format!("{:+.2}%", 100.0 * (m - pm) / pm),
        ]);
    }
    print!("{}", table.render());
    if let Some(spec) = args.get("profile") {
        // step processor profile (paper §4): the PM makespan comes from
        // Theorem 6's θ-inversion; Agreg's ≥ 1-processor guarantee is
        // proved against the profile's minimum step
        let profile = parse_profile(spec)?;
        let (agp, _) = agreg(&g, alpha, profile.min_p());
        let m = PmSolution::solve(&agp, alpha).makespan(&profile);
        println!(
            "PM makespan under step profile [{spec}] (agreg at p_min={}): {m:.6e}",
            profile.min_p()
        );
    }
    Ok(())
}

/// Distributed scheduling (paper §6): map a tree onto an N-node
/// platform, build one PM schedule per node, replay through the
/// cross-node DES and compare the mapping strategies.
pub fn distribute(args: &mut Args) -> Result<()> {
    use crate::dist::{self, MappingStrategy};
    use crate::model::Platform;
    use crate::net::{replay_link_faults, NetRecovery, NetSimConfig};
    use crate::sim::Policy;

    let net_spec = args.get("net").map(str::to_string);
    if net_spec.is_none() {
        for dep in ["link-faults", "timeout-factor", "recovery"] {
            if args.get(dep).is_some() {
                bail!("--{dep} needs --net LAT:BW");
            }
        }
    }
    let (name, tree, net_weights) = if net_spec.is_some() {
        let (name, tree, w, wsrc) = load_tree_mem(args)?;
        (name, tree, Some((w, wsrc)))
    } else {
        let (name, tree) = load_tree(args)?;
        (name, tree, None)
    };
    let alpha = args.get_alpha("alpha", DEFAULT_ALPHA)?;
    let lambda = args.get_f64_positive("lambda", 1.1)?;
    let strategy = MappingStrategy::parse(args.get("mapping").unwrap_or("pm"))?;
    let platform = if let Some(spec) = args.get("speeds") {
        let speeds = spec
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .with_context(|| format!("--speeds {spec}: bad entry {s:?}"))
            })
            .collect::<Result<Vec<f64>>>()?;
        Platform::Heterogeneous { speeds }
    } else {
        let nodes = args.get_usize("nodes", 2)?;
        let p = args.get_f64_positive("p", 8.0)?;
        if nodes <= 1 {
            Platform::Shared { p }
        } else {
            Platform::Homogeneous { nodes, p }
        }
    };
    platform.validate()?;
    println!(
        "tree {name}: {} tasks, alpha={alpha}, lambda={lambda}, {} nodes / {} cores pooled",
        tree.len(),
        platform.num_nodes(),
        platform.total_cores()
    );

    let mut table = Table::new(&[
        "mapping",
        "DES makespan",
        "/ lower bound",
        "vs single node",
        "cross-node stall",
    ]);
    let mut selected = None;
    for s in [
        MappingStrategy::Pm,
        MappingStrategy::Proportional,
        MappingStrategy::CriticalPath,
    ] {
        let d = dist::distribute(&tree, &platform, alpha, s, lambda)?;
        let marker = if s == strategy { "*" } else { "" };
        table.row(&[
            format!("{}{marker}", s.name()),
            format!("{:.6e}", d.makespan),
            format!("{:.4}", d.approx_ratio()),
            format!(
                "{:+.2}%",
                100.0 * (d.makespan - d.single_node_makespan) / d.single_node_makespan
            ),
            format!("{:.3e}", d.sim.cross_stall),
        ]);
        if s == strategy {
            selected = Some(d);
        }
    }
    print!("{}", table.render());
    let d = selected.expect("selected strategy is always in the sweep");
    println!(
        "selected mapping {}: lower bound {:.6e}, {} DES events, {} cross-node edges{}",
        strategy.name(),
        d.lower_bound,
        d.sim.events,
        d.sim.cross_edges,
        if d.fell_back { " (fell back to one node)" } else { "" }
    );
    let mut per_node = Table::new(&["node", "cores", "tasks", "local PM makespan", "DES finish"]);
    for (k, sched) in d.per_node.iter().enumerate() {
        per_node.row(&[
            format!("{k}"),
            format!("{}", d.platform.node_cores(k)),
            format!("{}", sched.spans.len()),
            format!("{:.6e}", sched.makespan),
            format!("{:.6e}", d.sim.node_finish[k]),
        ]);
    }
    print!("{}", per_node.render());

    if let Some(spec) = net_spec {
        // network-aware pipeline (DESIGN.md §15): price every cross
        // edge with the link model, let the candidate sweep see it,
        // and optionally stress the winner with link faults
        let (weights, wsrc) = net_weights.expect("loaded with memory weights under --net");
        let net = parse_net_spec(&spec, platform.num_nodes())?;
        let cfg = NetSimConfig {
            timeout_factor: args.get_f64_positive("timeout-factor", 4.0)?,
            recovery: match args.get("recovery").unwrap_or("best") {
                "best" => NetRecovery::Best,
                "wait" => NetRecovery::WaitOnly,
                other => bail!("--recovery {other:?}: want best|wait"),
            },
            ..NetSimConfig::default()
        };
        let nd = dist::distribute_networked(&tree, &platform, alpha, lambda, &weights, &net, &cfg)?;
        println!(
            "\nnetworked DES [--net {spec}] ({wsrc} contribution blocks): chose {}{}, \
             makespan {:.6e}",
            nd.chose,
            if nd.fell_back { " (fell back to one node)" } else { "" },
            nd.sim.makespan,
        );
        println!(
            "  gain vs comm-blind pm {:+.2}%, vs single node {:+.2}%; {} cross edges, \
             {:.3e} words moved, transfer stall {:.3e}, compute stall {:.3e}",
            nd.gain_comm_aware_vs_blind_pct(),
            100.0 * (nd.single_node_makespan - nd.sim.makespan) / nd.single_node_makespan,
            nd.sim.cross_edges,
            nd.sim.bytes_moved,
            nd.sim.transfer_stall,
            nd.sim.cross_stall,
        );
        if let Some(fspec) = args.get("link-faults").map(str::to_string) {
            let template = parse_fault_spec(&fspec)?;
            let trace = materialize_faults(&template, nd.sim.makespan);
            let run = |rec: NetRecovery| {
                let cfg = NetSimConfig { recovery: rec, ..cfg };
                replay_link_faults(
                    &tree,
                    alpha,
                    &platform,
                    &nd.mapping.node_of,
                    Policy::Pm,
                    &weights,
                    &net,
                    &cfg,
                    &trace,
                )
            };
            let best = run(NetRecovery::Best)?;
            let wait = run(NetRecovery::WaitOnly)?;
            println!(
                "link faults [{fspec}] ({} events; times and durations are fractions of \
                 the fault-free networked makespan {:.4e}):",
                trace.events.len(),
                best.fault_free_makespan,
            );
            let mut lt = Table::new(&[
                "recovery",
                "makespan",
                "overhead",
                "retransmits",
                "remaps",
                "words moved",
            ]);
            for (rn, rec, r) in [
                ("best", NetRecovery::Best, &best),
                ("wait", NetRecovery::WaitOnly, &wait),
            ] {
                let marker = if rec == cfg.recovery { "*" } else { "" };
                lt.row(&[
                    format!("{rn}{marker}"),
                    format!("{:.6e}", r.sim.makespan),
                    format!("{:+.2}%", 100.0 * r.overhead() / r.fault_free_makespan),
                    format!("{}", r.sim.retransmits),
                    format!("{}", r.sim.remaps),
                    format!("{:.3e}", r.sim.bytes_moved),
                ]);
            }
            print!("{}", lt.render());
        }
    }
    Ok(())
}

pub fn simulate(args: &mut Args) -> Result<()> {
    let trees = args.get_usize("trees", 100)?;
    let p = args.get_f64_positive("p", 40.0)?;
    let seed = args.get_usize("seed", 0xDA7A)? as u64;
    let max_nodes = args.get_usize("max-nodes", 20_000)?;
    let spec = DatasetSpec {
        random_trees: trees,
        min_nodes: 2_000,
        max_nodes,
        include_analysis_trees: true,
        seed,
    };
    let corpus = gen_dataset(&spec);
    println!("corpus: {} trees, p={p}", corpus.len());
    let mut table = Table::new(&[
        "alpha", "strategy", "d10", "q25", "median", "q75", "d90", "mean",
    ]);
    for alpha in [0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 1.0] {
        let mut div = Vec::with_capacity(corpus.len());
        let mut prop = Vec::with_capacity(corpus.len());
        for (_, tree) in &corpus {
            let (d, pr) = relative_distances(tree, alpha, p);
            div.push(d);
            prop.push(pr);
        }
        for (strat, data) in [("Divisible", &div), ("Proportional", &prop)] {
            let r = BoxplotRow::from_data(data);
            table.row(&[
                format!("{alpha:.2}"),
                strat.to_string(),
                format!("{:.2}", r.d10),
                format!("{:.2}", r.q25),
                format!("{:.2}", r.median),
                format!("{:.2}", r.q75),
                format!("{:.2}", r.d90),
                format!("{:.2}", r.mean),
            ]);
        }
    }
    print!("{}", table.render());
    if let Some(path) = args.get("trace-out").map(std::path::PathBuf::from) {
        // trace one representative corpus tree through the shared DES
        // (PM policy) and export its model-time span timeline
        use crate::sim::{simulate_traced, Policy};
        let alpha = args.get_alpha("alpha", DEFAULT_ALPHA)?;
        let Some((tname, tree)) = corpus.first() else {
            bail!("--trace-out needs a non-empty corpus (--trees >= 1)");
        };
        let (res, log) = simulate_traced(tree, alpha, p, Policy::Pm);
        crate::obs::write_chrome_trace(&log, &path)?;
        print!("{}", crate::obs::timeline_summary(&log));
        println!(
            "traced {tname} (alpha={alpha}, model makespan {:.4e}) to {}",
            res.makespan,
            path.display()
        );
    }
    if let Some(spec) = args.get("profile") {
        // step processor profile: per α, the corpus-mean PM makespan
        // under the profile (Theorem 6 θ-inversion) next to the
        // constant-p closed form at the profile's maximum
        let profile = parse_profile(spec)?;
        let mut ws = crate::sched::SchedWorkspace::new();
        let mut t2 = Table::new(&[
            "alpha",
            "mean PM makespan (profile)",
            "mean PM makespan (const max_p)",
        ]);
        for alpha in [0.7, 0.9, 1.0] {
            let (mut mp, mut mc) = (0.0f64, 0.0f64);
            for (_, tree) in &corpus {
                let g = SpGraph::from_tree(tree);
                let sol = ws.solve(&g, alpha);
                mp += sol.makespan(&profile);
                mc += sol.makespan_const(profile.max_p());
            }
            let k = corpus.len() as f64;
            t2.row(&[
                format!("{alpha:.2}"),
                format!("{:.6e}", mp / k),
                format!("{:.6e}", mc / k),
            ]);
        }
        println!("\nstep profile [{spec}]:");
        print!("{}", t2.render());
    }
    if let Some(fspec) = args.get("faults").map(str::to_string) {
        // fault replay (DESIGN.md §13): map each tree onto an N-node
        // platform, disturb the replay at fixed fractions of its
        // fault-free makespan, and compare the recovery policies.
        // Note the overhead of Best can be *negative*: a mid-run share
        // re-solve over the remaining forest is not bound by the static
        // schedule's equal-finish structure once shares hit the 1-core
        // speedup kink.
        use crate::dist::{map_tree, MappingStrategy};
        use crate::model::{FaultTrace, Platform};
        use crate::sim::{replay_faults_distributed, Policy, RecoveryPolicy};

        let template = parse_fault_spec(&fspec)?;
        let nodes = args.get_usize("nodes", 2)?;
        if nodes < 2 {
            bail!("--faults needs --nodes >= 2 (crash recovery re-maps onto survivors)");
        }
        let node_cores = args.get_f64_positive("node-cores", 8.0)?;
        let alpha = args.get_alpha("alpha", DEFAULT_ALPHA)?;
        let lambda = args.get_f64_positive("lambda", 1.1)?;
        let subset = args.get_usize("fault-trees", 6)?.min(corpus.len());
        let platform = Platform::Homogeneous { nodes, p: node_cores };
        platform.validate()?;
        println!(
            "\nfault replay [{fspec}] on {nodes} nodes x {node_cores} cores, alpha={alpha} \
             (event times are fractions of each tree's fault-free makespan):"
        );
        let mut ft = Table::new(&[
            "tree",
            "fault-free",
            "best",
            "overhead",
            "remap-only",
            "restart-only",
            "best vs restart",
            "lost work",
            "remapped",
        ]);
        for (tname, tree) in corpus.iter().take(subset) {
            let mapping = map_tree(tree, &platform, alpha, MappingStrategy::Pm, lambda);
            let run = |trace: &FaultTrace, rec: RecoveryPolicy| {
                replay_faults_distributed(
                    tree, alpha, &platform, &mapping.node_of, Policy::Pm, trace, rec,
                )
            };
            let mff = run(&FaultTrace::empty(), RecoveryPolicy::Best)?.makespan;
            let trace = materialize_faults(&template, mff);
            trace.validate(platform.num_nodes())?;
            let best = run(&trace, RecoveryPolicy::Best)?;
            let remap = run(&trace, RecoveryPolicy::RemapOnly)?;
            let restart = run(&trace, RecoveryPolicy::RestartOnly)?;
            ft.row(&[
                tname.clone(),
                format!("{mff:.4e}"),
                format!("{:.4e}", best.makespan),
                format!("{:+.2}%", 100.0 * best.recovery_overhead() / mff),
                format!("{:.4e}", remap.makespan),
                format!("{:.4e}", restart.makespan),
                format!(
                    "{:+.2}%",
                    100.0 * (best.makespan - restart.makespan) / restart.makespan
                ),
                format!("{:.3e}", best.lost_work),
                format!(
                    "{}{}",
                    best.remapped_subtrees,
                    if best.restarted { " (restart)" } else { "" }
                ),
            ]);
        }
        print!("{}", ft.render());
    }
    Ok(())
}

/// Memory-aware planning (`mem/`, DESIGN.md §12): sequential traversal
/// peaks (Liu vs default), the unbounded PM schedule's replayed peak,
/// memory-bounded schedules under a cap, and the makespan /
/// peak-memory Pareto front.
pub fn memory(args: &mut Args) -> Result<()> {
    use crate::mem::{bounded_schedule, liu_order, peak};
    use crate::sim::replay_memory;

    let (name, tree, w, source) = load_tree_mem(args)?;
    w.validate(&tree)?;
    let alpha = args.get_alpha("alpha", DEFAULT_ALPHA)?;
    let p = args.get_f64_positive("p", 8.0)?;
    let order_sel = args.get("order").unwrap_or("liu").to_string();
    if order_sel != "liu" && order_sel != "default" {
        anyhow::bail!("unknown --order {order_sel} (liu|default)");
    }
    println!(
        "tree {name}: {} tasks, alpha={alpha}, p={p}, weights: {source}",
        tree.len()
    );

    let default_peak = peak(&tree, &w, &tree.topo_up());
    let liu = liu_order(&tree, &w);
    let liu_peak = peak(&tree, &w, &liu);
    let reduction = 100.0 * (default_peak - liu_peak) / default_peak.max(1e-300);
    for (nm, pk) in [("default", default_peak), ("liu", liu_peak)] {
        let marker = if nm == order_sel { "*" } else { "" };
        println!("sequential peak ({nm}{marker}): {pk:.4e} words");
    }
    println!("liu reduction vs default order: {reduction:.2}%");

    let profile = Profile::constant(p);
    let unbounded = bounded_schedule(&tree, &w, alpha, &profile, f64::INFINITY);
    let replay = replay_memory(&tree, &w, &unbounded.schedule, None);
    println!(
        "unbounded PM: makespan {:.6e}, replayed peak {:.4e} words ({:.2}x the liu serial peak)",
        unbounded.makespan,
        replay.peak,
        replay.peak / liu_peak.max(1e-300)
    );

    let cap = if args.get("cap-ratio").is_some() {
        Some(args.get_f64_positive("cap-ratio", 1.0)? * replay.peak)
    } else if args.get("cap").is_some() {
        Some(args.get_f64_positive("cap", 1.0)?)
    } else {
        None
    };
    if let Some(cap) = cap {
        let b = bounded_schedule(&tree, &w, alpha, &profile, cap);
        let br = replay_memory(&tree, &w, &b.schedule, Some(cap));
        println!(
            "cap {cap:.4e} words: makespan {:.6e} ({:+.2}% vs unbounded), planned peak \
             {:.4e}, {} serialized nodes, feasible={}",
            b.makespan,
            100.0 * (b.makespan - unbounded.makespan) / unbounded.makespan,
            b.planned_peak,
            b.serialized,
            b.feasible
        );
        println!(
            "  DES replay: peak {:.4e} words, {} stalled tasks ({:.3e} stall time), {} forced",
            br.peak, br.stalled_tasks, br.stall_time, br.forced
        );
    }

    if args.has_flag("pareto") || args.get("pareto").is_some() {
        let points = args.get_usize("pareto", 6)?;
        let front = crate::mem::pareto_front(&tree, &w, alpha, p, points);
        let mut table = Table::new(&[
            "cap (words)",
            "makespan",
            "vs unbounded",
            "replay peak",
            "serialized",
        ]);
        let base = front.last().map(|pt| pt.makespan).unwrap_or(1.0);
        for pt in &front {
            table.row(&[
                format!("{:.4e}", pt.cap),
                format!("{:.6e}", pt.makespan),
                format!("{:+.2}%", 100.0 * (pt.makespan - base) / base),
                format!("{:.4e}", pt.replay_peak),
                format!("{}", pt.serialized),
            ]);
        }
        print!("{}", table.render());
    }
    Ok(())
}

/// Multi-tenant batch scheduling: generate a corpus of independent
/// trees and push them through the Agreg + PM pipeline on a thread
/// pool, reporting throughput (the heavy-traffic scenario `sched_perf`
/// tracks in EXPERIMENTS.md §Perf).
pub fn batch(args: &mut Args) -> Result<()> {
    use crate::sched::batch::{effective_threads, schedule_batch, BatchConfig};

    let trees_n = args.get_usize("trees", 200)?;
    let alpha = args.get_alpha("alpha", DEFAULT_ALPHA)?;
    let p = args.get_f64_positive("p", 40.0)?;
    let threads = args.get_usize("threads", 0)?;
    let min_nodes = args.get_usize("min-nodes", 1_000)?;
    let max_nodes = args.get_usize("max-nodes", 20_000)?;
    let seed = args.get_usize("seed", 0xDA7A)? as u64;
    let agreg_on = !args.has_flag("no-agreg");
    if agreg_on && p < 1.0 {
        bail!(
            "--p {p} is below one processor: the Agreg >= 1-processor guarantee \
             needs p >= 1 (pass --no-agreg to schedule raw pseudo-trees)"
        );
    }

    let spec = DatasetSpec {
        random_trees: trees_n,
        min_nodes,
        max_nodes,
        include_analysis_trees: false,
        seed,
    };
    let trees: Vec<TaskTree> = gen_dataset(&spec).into_iter().map(|(_, t)| t).collect();
    let total_tasks: usize = trees.iter().map(|t| t.len()).sum();
    let workers = effective_threads(threads);
    println!(
        "batch: {} trees / {} tasks, alpha={alpha}, p={p}, agreg={agreg_on}, {workers} workers",
        trees.len(),
        total_tasks
    );

    let mut table = Table::new(&["threads", "wall time", "trees/s", "Mtasks/s", "speedup"]);
    let mut base_secs = None;
    for t in [1usize, workers] {
        let cfg = BatchConfig { alpha, p, threads: t, agreg: agreg_on };
        let t0 = std::time::Instant::now();
        let results = schedule_batch(&trees, &cfg);
        let secs = t0.elapsed().as_secs_f64();
        anyhow::ensure!(results.len() == trees.len(), "lost batch results");
        if agreg_on {
            for r in &results {
                anyhow::ensure!(
                    r.min_share >= 1.0 - 1e-6,
                    "tree {} kept a sub-processor share {}",
                    r.index,
                    r.min_share
                );
            }
        }
        let base = *base_secs.get_or_insert(secs);
        table.row(&[
            format!("{t}"),
            format!("{:.3} s", secs),
            format!("{:.0}", trees.len() as f64 / secs),
            format!("{:.2}", total_tasks as f64 / secs / 1e6),
            format!("{:.2}x", base / secs),
        ]);
        if workers == 1 {
            break; // single-core machine: one row is the whole story
        }
    }
    print!("{}", table.render());
    Ok(())
}

pub fn factorize(args: &mut Args) -> Result<()> {
    use crate::exec::{
        execute_malleable_capped_traced, execute_malleable_faulty_traced, execute_malleable_traced,
        execute_parallel_traced, execute_serial_traced, FaultPlan,
    };
    use crate::frontal::{multifrontal, FrontConfig, NaiveBackend, PjrtBackend, RustBackend, SimdMode};
    use crate::obs::TraceSink;

    let (name, a, perm) = load_problem(args)?;
    // --trace-out FILE.json: record a wall-clock span timeline and
    // export it as a Chrome trace (MALLTREE_TRACE=on|off overrides)
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let sink = TraceSink::from_env(trace_out.is_some());
    let amalg = args.get_usize("amalgamate", 4)?;
    let alpha = args.get_alpha("alpha", DEFAULT_ALPHA)?;
    let p = args.get_f64_positive("p", 8.0)?;
    let workers = args.get_usize_positive("workers", 4)?;
    // --malleable: realize the schedule's fractional shares as worker
    // teams per front (share-driven team sizes + intra-front tile
    // parallelism) instead of one worker per front
    let malleable = args.has_flag("malleable");
    // --mem-cap WORDS: MemGauge-backed admission gate (malleable only)
    let mem_cap = args.get_usize("mem-cap", 0)?;
    if mem_cap > 0 && !malleable {
        bail!("--mem-cap needs --malleable (the admission gate lives in the malleable crew)");
    }
    // --fault-plan / --elastic: self-healing malleable run (DESIGN.md
    // §13) with injected transient failures and crew leave/join events
    let fault_spec = args.get("fault-plan").map(str::to_string);
    let elastic_spec = args.get("elastic").map(str::to_string);
    let faulted = fault_spec.is_some() || elastic_spec.is_some();
    if faulted && !malleable {
        bail!("--fault-plan/--elastic need --malleable (retries requeue into the team crew)");
    }
    if faulted && mem_cap > 0 {
        bail!(
            "--fault-plan/--elastic cannot combine with --mem-cap \
             (the admission gate's reservation does not survive a retry)"
        );
    }
    // backend selection: blocked tiled kernels (default), the unblocked
    // naive oracle, or the PJRT accelerator queue (--pjrt is kept as an
    // alias for --backend pjrt)
    let backend_name = args
        .get("backend")
        .unwrap_or(if args.has_flag("pjrt") { "pjrt" } else { "blocked" })
        .to_string();
    // --block N / --simd auto|off|force: kernel tile geometry and ISA
    // policy for the blocked backend, validated once at construction
    let block = args.get_usize("block", crate::frontal::dense::BLOCK)?;
    let simd = SimdMode::parse(args.get("simd").unwrap_or("auto")).context("--simd")?;
    let rust_backend = RustBackend::with_config(FrontConfig { block, simd })?;
    let at: AssemblyTree = symbolic::analyze(&a, &perm, amalg)?;
    let ap = a.permute_sym(&at.symbolic.perm)?;
    let pm = PmSchedule::for_tree(&at.tree, alpha, &Profile::constant(p));
    println!(
        "problem {name}: {} supernodes, PM virtual makespan {:.3e}",
        at.tree.len(),
        pm.schedule.makespan
    );
    let fault_plan = if faulted {
        let mut plan = FaultPlan::new();
        plan.max_retries = args.get_usize("retries", 3)?;
        plan.backoff_ms = args.get_usize("backoff-ms", 1)? as u64;
        if let Some(s) = &fault_spec {
            plan.parse_inject(s, at.tree.len())?;
        }
        if let Some(s) = &elastic_spec {
            plan.parse_elastic(s)?;
        }
        Some(plan)
    } else {
        None
    };
    let (fact, report) = match backend_name.as_str() {
        "pjrt" => {
            if malleable {
                bail!("--malleable needs a thread-crew backend (blocked|naive), not pjrt");
            }
            let dir = std::path::PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
            let rt = std::sync::Arc::new(crate::runtime::Runtime::cpu(&dir)?);
            println!("pjrt platform: {}", rt.platform());
            let backend = PjrtBackend::new(rt);
            execute_serial_traced(&at, &ap, &pm.schedule, &backend, sink)?
        }
        "naive" if fault_plan.is_some() => {
            let plan = fault_plan.as_ref().expect("guarded by is_some");
            execute_malleable_faulty_traced(&at, &ap, &pm.schedule, &NaiveBackend, workers, plan, sink)?
        }
        "naive" if malleable && mem_cap > 0 => {
            execute_malleable_capped_traced(&at, &ap, &pm.schedule, &NaiveBackend, workers, mem_cap, sink)?
        }
        "naive" if malleable => {
            execute_malleable_traced(&at, &ap, &pm.schedule, &NaiveBackend, workers, sink)?
        }
        "naive" => execute_parallel_traced(&at, &ap, &pm.schedule, &NaiveBackend, workers, sink)?,
        "blocked" | "rust" if fault_plan.is_some() => {
            let plan = fault_plan.as_ref().expect("guarded by is_some");
            execute_malleable_faulty_traced(&at, &ap, &pm.schedule, &rust_backend, workers, plan, sink)?
        }
        "blocked" | "rust" if malleable && mem_cap > 0 => {
            execute_malleable_capped_traced(&at, &ap, &pm.schedule, &rust_backend, workers, mem_cap, sink)?
        }
        "blocked" | "rust" if malleable => {
            execute_malleable_traced(&at, &ap, &pm.schedule, &rust_backend, workers, sink)?
        }
        "blocked" | "rust" => {
            execute_parallel_traced(&at, &ap, &pm.schedule, &rust_backend, workers, sink)?
        }
        other => bail!("unknown --backend {other} (blocked|naive|pjrt)"),
    };
    if matches!(backend_name.as_str(), "blocked" | "rust") {
        println!(
            "kernels: block {}, simd {} → dispatched isa {}",
            rust_backend.cfg().block,
            simd.name(),
            rust_backend.isa().name()
        );
    }
    println!("{}", report.render());
    if report.malleable {
        for row in report.occupancy() {
            let hi = if row.hi == usize::MAX {
                "∞".to_string()
            } else {
                row.hi.to_string()
            };
            println!(
                "  fronts of order ({}, {hi}]: {} fronts, avg team {:.2}, max team {}",
                row.lo, row.fronts, row.avg_team, row.max_team
            );
        }
    }
    if let Some(path) = &trace_out {
        match &report.trace {
            Some(log) => {
                crate::obs::write_chrome_trace(log, path)?;
                print!("{}", crate::obs::timeline_summary(log));
                println!("trace written to {}", path.display());
            }
            None => println!("--trace-out ignored: tracing disabled via MALLTREE_TRACE"),
        }
    }
    let r = multifrontal::residual(&at, &ap, &fact);
    println!("relative residual |PAP' - LL'|_F / |A|_F = {r:.3e}");
    if r > 1e-3 {
        bail!("residual too large");
    }
    Ok(())
}

/// Close the α loop from the system's own telemetry (DESIGN.md §17):
/// factorize the problem with worker teams of several sizes, fit the
/// malleability exponent from the recorded Factor spans, and report
/// the drift between the `L/p^α` model and the executed timeline
/// under the assumed vs the fitted α — plus a step `--profile` spec
/// distilled from the trace's occupancy curve.
pub fn calibrate(args: &mut Args) -> Result<()> {
    use crate::exec::execute_malleable_traced;
    use crate::frontal::{FrontConfig, RustBackend, SimdMode};
    use crate::obs::{self, TraceSink};

    let (name, a, perm) = load_problem(args)?;
    let amalg = args.get_usize("amalgamate", 4)?;
    let assumed = args.get_alpha("alpha", DEFAULT_ALPHA)?;
    let sweep_spec = args.get("workers-sweep").unwrap_or("2,4,8").to_string();
    let mut sweep = Vec::new();
    for tok in sweep_spec.split(',') {
        let w: usize =
            tok.trim().parse().with_context(|| format!("--workers-sweep {sweep_spec:?}"))?;
        if w == 0 {
            bail!("--workers-sweep entries must be >= 1");
        }
        sweep.push(w);
    }
    sweep.sort_unstable();
    sweep.dedup();
    if sweep.len() < 2 {
        bail!("--workers-sweep needs >= 2 distinct team sizes (one size cannot identify alpha)");
    }
    let block = args.get_usize("block", crate::frontal::dense::BLOCK)?;
    let simd = SimdMode::parse(args.get("simd").unwrap_or("auto")).context("--simd")?;
    let backend = RustBackend::with_config(FrontConfig { block, simd })?;
    let at: AssemblyTree = symbolic::analyze(&a, &perm, amalg)?;
    let ap = a.permute_sym(&at.symbolic.perm)?;
    let widths: Vec<usize> = at.symbolic.supernodes.iter().map(|s| s.front_order()).collect();
    println!(
        "calibrate {name}: {} supernodes, assumed alpha {assumed}, worker sweep {sweep:?}",
        at.tree.len()
    );
    let mut logs = Vec::new();
    for &w in &sweep {
        let pm = PmSchedule::for_tree(&at.tree, assumed, &Profile::constant(w as f64));
        // tracing is the whole point of this command, so the sink is
        // unconditional (MALLTREE_TRACE only gates opportunistic runs)
        let (_, report) =
            execute_malleable_traced(&at, &ap, &pm.schedule, &backend, w, TraceSink::Buffer)?;
        let log = report.trace.context("traced run returned no trace")?;
        println!("  workers {w}: wall {:.3}s, {} spans", report.wall_seconds, log.spans.len());
        logs.push((w, log));
    }
    let refs: Vec<&obs::TraceLog> = logs.iter().map(|(_, l)| l).collect();
    let cal = obs::calibrate(&refs, Some(&widths))?;
    println!(
        "fitted alpha = {:.3} (r² = {:.4}, {} samples, unit cost {:.3e} ns/flop) vs assumed {assumed}",
        cal.alpha, cal.fit.r2, cal.samples, cal.unit_cost
    );
    if !cal.per_width.is_empty() {
        let mut t = Table::new(&["front width", "samples", "alpha", "r2"]);
        for wf in &cal.per_width {
            let hi = if wf.hi == usize::MAX { "∞".to_string() } else { wf.hi.to_string() };
            t.row(&[
                format!("({}, {hi}]", wf.lo),
                format!("{}", wf.samples),
                format!("{:.3}", wf.alpha),
                format!("{:.4}", wf.r2),
            ]);
        }
        print!("{}", t.render());
    }
    // drift on the widest-team run: predicted vs executed durations
    // and the §7 mis-specification cost, measured instead of simulated
    let (w_last, log_last) = logs.last().expect("sweep has >= 2 entries");
    let m_assumed =
        PmSchedule::for_tree(&at.tree, assumed, &Profile::constant(*w_last as f64)).schedule.makespan;
    // a noisy host can fit an exponent outside the model's (0, 1]
    // domain; the schedule re-solve needs a legal α
    let fitted_for_solve = cal.alpha.clamp(0.05, 1.0);
    let m_fitted = PmSchedule::for_tree(&at.tree, fitted_for_solve, &Profile::constant(*w_last as f64))
        .schedule
        .makespan;
    let drift = obs::drift_report(log_last, &widths, &cal, assumed, m_assumed, m_fitted);
    let mut t = Table::new(&["front width", "fronts", "err% (assumed)", "err% (fitted)"]);
    for r in &drift.rows {
        let hi = if r.hi == usize::MAX { "∞".to_string() } else { r.hi.to_string() };
        t.row(&[
            format!("({}, {hi}]", r.lo),
            format!("{}", r.fronts),
            format!("{:.1}", r.err_assumed_pct),
            format!("{:.1}", r.err_fitted_pct),
        ]);
    }
    print!("{}", t.render());
    println!(
        "per-front drift: {:.1}% under assumed alpha, {:.1}% under fitted; makespan \
         ({w_last} workers): measured {:.3e} ns, predicted {:.3e} (assumed, {:.1}% off) \
         / {:.3e} (fitted, {:.1}% off)",
        drift.overall_assumed_pct,
        drift.overall_fitted_pct,
        drift.measured_makespan,
        drift.predicted_assumed,
        drift.makespan_err_assumed_pct,
        drift.predicted_fitted,
        drift.makespan_err_fitted_pct,
    );
    let (_, spec) = obs::profile_from_trace(log_last, 8, cal.unit_cost)?;
    println!("occupancy profile (feed back via --profile): {spec}");
    if let Some(path) = args.get("trace-out").map(std::path::PathBuf::from) {
        obs::write_chrome_trace(log_last, &path)?;
        println!("trace ({w_last} workers) written to {}", path.display());
    }
    Ok(())
}

pub fn kernelsim(args: &mut Args) -> Result<()> {
    let kind = args.get("kind").unwrap_or("cholesky").to_string();
    let n = args.get_usize("n", 20_000)?;
    let m = args.get_usize("m", 4096)?;
    let b = args.get_usize("b", 256)?;
    let pmax = args.get_usize("pmax", 40)?;
    let machine = MachineModel::default();
    let dag = match kind.as_str() {
        "cholesky" => KernelDag::cholesky(n.div_ceil(b), b),
        "qr" => KernelDag::qr(m.div_ceil(b), n.div_ceil(b), b),
        "frontal1d" => KernelDag::frontal(m, n, 32, true),
        "frontal2d" => KernelDag::frontal(m, n, b, false),
        other => bail!("unknown --kind {other} (cholesky|qr|frontal1d|frontal2d)"),
    };
    println!(
        "{kind} n={n} b={b}: {} kernels, {:.3e} flops, cp {:.3e}",
        dag.len(),
        dag.total_flops(),
        dag.critical_path()
    );
    let curve = timing_curve(&dag, pmax, &machine);
    let mut table = Table::new(&["p", "T(p)", "speedup"]);
    let t1 = curve[0].1;
    for &(p, t) in &curve {
        table.row(&[
            format!("{p:.0}"),
            format!("{t:.4e}"),
            format!("{:.2}", t1 / t),
        ]);
    }
    print!("{}", table.render());
    let pcap = args.get_f64_positive("pcap", 10.0)?;
    let (alpha, fit) = fit_alpha(&curve, pcap)?;
    println!("alpha = {alpha:.3} (r² = {:.4}, p <= {pcap})", fit.r2);
    Ok(())
}

/// Online multi-tenant scheduling service (DESIGN.md §14): replay a
/// job-arrival stream through the admission-controlled service and
/// report throughput, sojourn quantiles and SLO attainment.
pub fn serve(args: &mut Args) -> Result<()> {
    use crate::online::{
        job_stream, jobs_from_trace, parse_arrival_spec, ArrivalSource, FairnessMode,
        OverloadPolicy, ServiceConfig, StreamSpec,
    };
    use crate::sim::simulate_online;
    use crate::util::retry::LinearBackoff;

    let spec = args.get("arrivals").unwrap_or("poisson:2").to_string();
    let source = parse_arrival_spec(&spec)?;
    let alpha = args.get_alpha("alpha", DEFAULT_ALPHA)?;
    let p = args.get_usize_positive("p", 8)?;
    let queue_cap = args.get_usize("admit", 8)?;
    // inf disables the implied deadline, so the positive getter's
    // finiteness requirement is relaxed for this one flag
    let deadline_ratio = args.get_f64("deadline-ratio", f64::INFINITY)?;
    if deadline_ratio.is_nan() || deadline_ratio <= 0.0 {
        bail!("--deadline-ratio must be > 0 (got {deadline_ratio}; inf disables deadlines)");
    }
    let mode = FairnessMode::parse(args.get("policy").unwrap_or("makespan"))?;
    let overload = OverloadPolicy::parse(args.get("overload").unwrap_or("reject"))?;
    let degrade_factor = args.get_f64_positive("degrade-factor", 0.5)?;
    let retries = args.get_usize("retries", 3)?;
    let backoff = args.get_f64_nonneg("backoff", 0.5)?;
    let cfg = ServiceConfig {
        alpha,
        p,
        queue_cap,
        deadline_ratio,
        mode,
        overload,
        defer: LinearBackoff::new(backoff, retries),
        degrade_factor,
    };
    cfg.validate()?;

    let jobs = match source {
        ArrivalSource::Process(process) => {
            let stream = StreamSpec {
                jobs: args.get_usize("jobs", 200)?,
                tenants: args.get_usize_positive("tenants", 4)?,
                min_nodes: args.get_usize_positive("min-nodes", 20)?,
                max_nodes: args.get_usize_positive("max-nodes", 80)?,
                seed: args.get_usize("seed", 0xDA7A)? as u64,
            };
            job_stream(process, &stream)
        }
        ArrivalSource::Trace(path) => jobs_from_trace(&path)?,
    };
    println!(
        "serve: {} jobs [{spec}], alpha={alpha}, p={p}, queue cap {queue_cap}, \
         deadline ratio {deadline_ratio}, mode {mode:?}, overload {overload:?}",
        jobs.len()
    );
    let report = simulate_online(&jobs, cfg)?;
    anyhow::ensure!(report.conserved(), "outcome conservation violated");
    let mut table = Table::new(&["metric", "value"]);
    for (k, v) in [
        ("submitted", format!("{}", report.submitted)),
        ("completed", format!("{}", report.completed)),
        ("shed", format!("{}", report.shed)),
        ("timed out", format!("{}", report.timed_out)),
        ("horizon", format!("{:.4e}", report.horizon)),
        ("throughput (jobs/s)", format!("{:.4}", report.throughput)),
        ("p50 sojourn", format!("{:.4e}", report.p50_sojourn)),
        ("p99 sojourn", format!("{:.4e}", report.p99_sojourn)),
        ("mean sojourn", format!("{:.4e}", report.mean_sojourn)),
        ("SLO attainment", format!("{:.3}", report.slo_attainment)),
        ("max queue depth", format!("{}", report.max_queue)),
        ("events / resolves", format!("{} / {}", report.events, report.resolves)),
        ("deferred / degraded", format!("{} / {}", report.deferred, report.degraded)),
    ] {
        table.row(&[k.to_string(), v]);
    }
    print!("{}", table.render());
    Ok(())
}

pub fn dataset_cmd_impl(args: &mut Args) -> Result<()> {
    let out = std::path::PathBuf::from(args.get("out").unwrap_or("dataset"));
    std::fs::create_dir_all(&out)?;
    let spec = DatasetSpec {
        random_trees: args.get_usize("trees", 600)?,
        min_nodes: args.get_usize("min-nodes", 2_000)?,
        max_nodes: args.get_usize("max-nodes", 50_000)?,
        include_analysis_trees: !args.has_flag("no-analysis"),
        seed: args.get_usize("seed", 0xDA7A)? as u64,
    };
    let corpus = gen_dataset(&spec);
    for (name, tree) in &corpus {
        crate::workload::write_tree(tree, &out.join(format!("{name}.tree")))?;
    }
    println!("wrote {} trees to {}", corpus.len(), out.display());
    Ok(())
}

pub fn dataset(args: &mut Args) -> Result<()> {
    dataset_cmd_impl(args)
}

pub fn figures(args: &mut Args) -> Result<()> {
    // Thin wrapper: the heavy lifting (and timing) lives in the bench
    // binaries; this regenerates quick versions of every artifact.
    println!("== Table 1/2 + Figures 2-6 (kernel-DAG simulator, reduced sweep) ==");
    let machine = MachineModel::default();
    let mut table = Table::new(&["experiment", "size", "alpha", "r2"]);
    let cases: Vec<(&str, KernelDag)> = vec![
        ("fig2_qr_M1024_N5000", KernelDag::qr(4, 20, 256)),
        ("fig3_qr_M4096_N10000", KernelDag::qr(16, 40, 256)),
        ("fig4_chol_N10000", KernelDag::cholesky(40, 256)),
        ("fig5_frontal1d_10000x2500", KernelDag::frontal(10_000, 2_500, 32, true)),
        ("fig6_frontal2d_10000x2500", KernelDag::frontal(10_000, 2_500, 256, false)),
    ];
    for (name, dag) in cases {
        let curve = timing_curve(&dag, 20, &machine);
        let (alpha, fit) = fit_alpha(&curve, 10.0)?;
        table.row(&[
            name.to_string(),
            format!("{}", dag.len()),
            format!("{alpha:.3}"),
            format!("{:.4}", fit.r2),
        ]);
    }
    print!("{}", table.render());

    println!("\n== Figures 13/14 (reduced corpus) ==");
    let mut a2 = Args::new(vec![
        "--trees".into(),
        "24".into(),
        "--max-nodes".into(),
        "8000".into(),
        "--p".into(),
        args.get("p").unwrap_or("40").to_string(),
    ]);
    simulate(&mut a2)?;

    println!("\n== Algorithm 11 / 12 quality (random instances) ==");
    let mut rng = Rng::new(0xF16);
    let mut table = Table::new(&["instance", "algorithm", "ratio to bound"]);
    for i in 0..5 {
        let n = 8;
        let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(1.0, 50.0)).collect();
        let alpha = 0.9;
        let (_, opt) = crate::dist::independent_optimal(&lens, alpha, 4.0, 4.0);
        let mut parents = vec![0usize; n + 1];
        let mut all = vec![0.0];
        all.extend_from_slice(&lens);
        for p in parents.iter_mut().skip(1) {
            *p = 0;
        }
        let tree = TaskTree::from_parents(&parents, &all)?;
        let h = crate::dist::homog_approx(&tree, alpha, 4.0);
        table.row(&[
            format!("homog_{i}"),
            "Alg11".into(),
            format!("{:.4}", h.makespan / opt),
        ]);
        let het = crate::dist::het_schedule(&lens, alpha, 6.0, 2.0, 1.1);
        let (_, opt_het) = crate::dist::independent_optimal(&lens, alpha, 6.0, 2.0);
        table.row(&[
            format!("het_{i}"),
            "Alg12".into(),
            format!("{:.4}", het.makespan / opt_het),
        ]);
    }
    print!("{}", table.render());
    let _ = crate::config::Strategy::Pm; // silence unused in minimal builds
    Ok(())
}

// keep Strategy referenced for the library surface
#[allow(dead_code)]
fn _strategy_used(s: Strategy) -> Strategy {
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::new(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn parse_profile_accepts_step_specs() {
        let pr = parse_profile("1:2,0.5:8,3:4").unwrap();
        assert_eq!(pr.at(0.5), 2.0);
        assert_eq!(pr.at(1.2), 8.0);
        assert_eq!(pr.at(100.0), 4.0); // last step persists
        assert_eq!(pr.min_p(), 2.0);
        assert_eq!(pr.max_p(), 8.0);
        assert!(parse_profile("1:2,banana").is_err());
        assert!(parse_profile("1").is_err());
        assert!(parse_profile("0:2").is_err()); // zero duration
    }

    #[test]
    fn memory_command_runs_on_grid_and_rejects_bad_order() {
        let mut a = args("--grid2d 8 --alpha 0.9 -p 4 --pareto 3 --cap-ratio 0.8");
        memory(&mut a).unwrap();
        let mut bad = args("--grid2d 8 --order sideways");
        assert!(memory(&mut bad).is_err());
    }

    #[test]
    fn schedule_command_prints_profile_makespan() {
        let mut a = args("--grid2d 8 --alpha 0.9 -p 6 --profile 1:2,1:6");
        schedule(&mut a).unwrap();
        let mut bad = args("--grid2d 8 --profile 1:2:3");
        assert!(schedule(&mut bad).is_err());
    }

    #[test]
    fn factorize_rejects_mem_cap_without_malleable() {
        let mut a = args("--grid2d 6 --mem-cap 1000");
        assert!(factorize(&mut a).is_err());
    }

    #[test]
    fn factorize_rejects_bad_kernel_flags() {
        for bad in [
            "--grid2d 6 --block 0",
            "--grid2d 6 --block 4",
            "--grid2d 6 --block 2048",
            "--grid2d 6 --block banana",
            "--grid2d 6 --simd banana",
        ] {
            let mut a = args(bad);
            assert!(factorize(&mut a).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn factorize_runs_with_explicit_kernel_config() {
        // simd off keeps this deterministic on any host; block 32 is a
        // non-default tile edge so the cfg actually flows through
        let mut a = args("--grid2d 8 --block 32 --simd off --workers 2 --malleable");
        factorize(&mut a).unwrap();
    }

    #[test]
    fn calibrate_rejects_degenerate_sweeps() {
        for bad in [
            "--grid2d 6 --workers-sweep 4",
            "--grid2d 6 --workers-sweep 2,2",
            "--grid2d 6 --workers-sweep 0,2",
            "--grid2d 6 --workers-sweep banana",
            "--workers-sweep 1,2", // no problem selected
        ] {
            let mut a = args(bad);
            assert!(calibrate(&mut a).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn calibrate_fits_alpha_from_its_own_traced_runs() {
        let dir = std::env::temp_dir().join("malltree_cli_calibrate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("calibrate_trace.json");
        let _ = std::fs::remove_file(&path);
        // the calibrate sink is unconditional, so this holds even under
        // the CI MALLTREE_TRACE=off test leg
        let mut a = args(&format!(
            "--grid2d 8 --workers-sweep 1,2 --simd off --trace-out {}",
            path.display()
        ));
        calibrate(&mut a).unwrap();
        let json = std::fs::read_to_string(&path).unwrap();
        let log = crate::obs::parse_chrome_trace(&json).unwrap();
        log.validate().unwrap();
        assert_eq!(log.source, "exec");
        assert!(log.spans_of(crate::obs::SpanKind::Factor).count() > 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn factorize_and_simulate_export_chrome_traces() {
        let dir = std::env::temp_dir().join("malltree_cli_trace_test");
        std::fs::create_dir_all(&dir).unwrap();

        let fpath = dir.join("factorize_trace.json");
        let _ = std::fs::remove_file(&fpath);
        let mut a = args(&format!(
            "--grid2d 8 --simd off --workers 2 --malleable --trace-out {}",
            fpath.display()
        ));
        factorize(&mut a).unwrap();
        // the factorize sink honors MALLTREE_TRACE, so the CI trace-off
        // leg legitimately writes nothing
        let forced_off = matches!(
            std::env::var("MALLTREE_TRACE").ok().as_deref(),
            Some("off") | Some("0") | Some("false")
        );
        if forced_off {
            assert!(!fpath.exists(), "null sink must not write a trace");
        } else {
            let log =
                crate::obs::parse_chrome_trace(&std::fs::read_to_string(&fpath).unwrap()).unwrap();
            log.validate().unwrap();
            assert_eq!(log.source, "exec");
            assert_eq!(log.workers, 2);
            let _ = std::fs::remove_file(&fpath);
        }

        let spath = dir.join("simulate_trace.json");
        let _ = std::fs::remove_file(&spath);
        let mut b = args(&format!(
            "--trees 2 --max-nodes 3000 --trace-out {}",
            spath.display()
        ));
        simulate(&mut b).unwrap();
        let log =
            crate::obs::parse_chrome_trace(&std::fs::read_to_string(&spath).unwrap()).unwrap();
        log.validate().unwrap();
        assert_eq!(log.source, "sim-des");
        let _ = std::fs::remove_file(&spath);
    }

    #[test]
    fn parse_fault_spec_reads_all_event_kinds() {
        use crate::model::FaultKind;
        let t = parse_fault_spec("crash:1@0.5, leave:0:2@0.1, join:0:2@0.7, slow:1:0.5:0.2@0.3")
            .unwrap();
        assert_eq!(t.len(), 4);
        assert_eq!(t[0], (0.5, FaultKind::Crash { node: 1 }));
        assert_eq!(t[1], (0.1, FaultKind::Leave { node: 0, cores: 2.0 }));
        assert_eq!(t[2], (0.7, FaultKind::Join { node: 0, cores: 2.0 }));
        assert_eq!(
            t[3],
            (0.3, FaultKind::Slowdown { node: 1, factor: 0.5, duration: 0.2 })
        );
        // slowdown durations scale with the fault-free makespan too
        let trace = materialize_faults(&t, 10.0);
        assert_eq!(trace.events[0].time, 1.0); // sorted by time
        match trace.events[1].kind {
            FaultKind::Slowdown { duration, .. } => assert_eq!(duration, 2.0),
            ref k => panic!("expected slowdown, got {k:?}"),
        }
        for bad in ["crash:1", "crash:x@0.5", "melt:1@0.5", "crash:1@-0.1", ""] {
            assert!(parse_fault_spec(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn parse_fault_spec_reads_link_events_and_scales_their_durations() {
        use crate::model::FaultKind;
        let t = parse_fault_spec("linkslow:0:1:0.25:0.3@0.2, linkdown:1:0:0.2@0.5").unwrap();
        assert_eq!(
            t[0],
            (0.2, FaultKind::LinkDegrade { a: 0, b: 1, factor: 0.25, duration: 0.3 })
        );
        assert_eq!(t[1], (0.5, FaultKind::LinkDown { a: 1, b: 0, duration: 0.2 }));
        let trace = materialize_faults(&t, 10.0);
        match trace.events[0].kind {
            FaultKind::LinkDegrade { duration, .. } => assert_eq!(duration, 3.0),
            ref k => panic!("expected linkslow, got {k:?}"),
        }
        match trace.events[1].kind {
            FaultKind::LinkDown { duration, .. } => assert_eq!(duration, 2.0),
            ref k => panic!("expected linkdown, got {k:?}"),
        }
        for bad in [
            "linkslow:0:1:0.5@0.2", // missing duration
            "linkdown:0@0.5",
            "linkslow:0:x:0.5:1@0.2",
            "linkdown:0:1:0.2",
        ] {
            assert!(parse_fault_spec(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn distribute_networked_command_runs_and_replays_link_faults() {
        let mut a = args(
            "--grid2d 8 --nodes 2 -p 4 --net 0.05:2 \
             --link-faults linkslow:0:1:0.25:0.3@0.2,linkdown:0:1:0.2@0.5 \
             --timeout-factor 2 --recovery best",
        );
        distribute(&mut a).unwrap();
        // free-net spelling works too, and the tree path still loads
        let mut b = args("--grid2d 8 --nodes 2 -p 4 --net 0:inf");
        distribute(&mut b).unwrap();
    }

    #[test]
    fn distribute_rejects_bad_network_flags() {
        for bad in [
            "--grid2d 8 --nodes 2 --net 5",
            "--grid2d 8 --nodes 2 --net a:b",
            "--grid2d 8 --nodes 2 --net 1:0",
            "--grid2d 8 --nodes 2 --net 1:-3",
            "--grid2d 8 --nodes 2 --net inf:2",
            "--grid2d 8 --nodes 2 --link-faults linkdown:0:1:0.2@0.5",
            "--grid2d 8 --nodes 2 --timeout-factor 2",
            "--grid2d 8 --nodes 2 --recovery wait",
            "--grid2d 8 --nodes 2 --net 0.1:2 --recovery sometimes",
            "--grid2d 8 --nodes 2 --net 0.1:2 --timeout-factor 0",
            "--grid2d 8 --nodes 2 --net 0.1:2 --link-faults crash:1@0.5",
            "--grid2d 8 --nodes 2 --net 0.1:2 --link-faults linkdown:0:5:0.2@0.5",
        ] {
            let mut a = args(bad);
            assert!(distribute(&mut a).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn factorize_rejects_fault_plans_outside_the_malleable_crew() {
        let mut a = args("--grid2d 6 --fault-plan every:4:1");
        assert!(factorize(&mut a).is_err(), "--fault-plan without --malleable");
        let mut b = args("--grid2d 6 --elastic -1@2");
        assert!(factorize(&mut b).is_err(), "--elastic without --malleable");
        let mut c = args("--grid2d 6 --malleable --mem-cap 100000 --fault-plan every:4:1");
        assert!(factorize(&mut c).is_err(), "--fault-plan with --mem-cap");
    }

    #[test]
    fn factorize_heals_injected_faults_and_elastic_crews() {
        let mut a = args(
            "--grid2d 8 --malleable --workers 4 --backoff-ms 0 \
             --fault-plan every:5:1 --elastic -2@3,+1@10",
        );
        factorize(&mut a).unwrap();
    }

    #[test]
    fn serve_command_runs_and_validates_its_flags() {
        let mut a = args(
            "--arrivals poisson:4 --jobs 30 --min-nodes 3 --max-nodes 10 -p 4 \
             --admit 4 --deadline-ratio 4 --policy fair --overload defer",
        );
        serve(&mut a).unwrap();
        for bad in [
            "--arrivals poisson:0",
            "--arrivals sawtooth:2",
            "--arrivals poisson:2 --alpha 2",
            "--arrivals poisson:2 --alpha NaN",
            "--arrivals poisson:2 --deadline-ratio 0",
            "--arrivals poisson:2 --deadline-ratio NaN",
            "--arrivals poisson:2 --policy lifo",
            "--arrivals poisson:2 --overload panic",
            "--arrivals poisson:2 --degrade-factor 0",
            "--arrivals poisson:2 -p 0",
        ] {
            let mut a = args(bad);
            assert!(serve(&mut a).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn commands_reject_invalid_numeric_flags() {
        let mut a = args("--grid2d 8 --alpha NaN");
        assert!(schedule(&mut a).is_err(), "NaN alpha");
        let mut b = args("--trees 2 --alpha -0.5");
        assert!(batch(&mut b).is_err(), "negative alpha");
        let mut c = args("--grid2d 8 --alpha 0.9 -p 0");
        assert!(schedule(&mut c).is_err(), "zero p");
        let mut d = args("--grid2d 8 --cap-ratio -1");
        assert!(memory(&mut d).is_err(), "negative cap ratio");
        let mut e = args("--grid2d 8 --cap-ratio NaN");
        assert!(memory(&mut e).is_err(), "NaN cap ratio");
    }

    #[test]
    fn simulate_replays_fault_traces_over_the_corpus() {
        let mut a = args(
            "--trees 2 --max-nodes 4000 -p 8 --fault-trees 2 --nodes 2 \
             --faults crash:1@0.5,slow:0:0.5:0.2@0.1",
        );
        simulate(&mut a).unwrap();
        let mut bad = args("--trees 2 --max-nodes 4000 --faults crash:1@0.5 --nodes 1");
        assert!(simulate(&mut bad).is_err(), "--faults on one node");
        let mut malformed = args("--trees 2 --max-nodes 4000 --faults melt:1@0.5");
        assert!(simulate(&mut malformed).is_err());
    }
}
