//! Launcher: hand-rolled CLI (clap is unavailable offline).
//!
//! ```text
//! malltree analyze   --grid2d 32 [--amalgamate 4]        symbolic analysis summary
//! malltree schedule  --grid2d 32 --alpha 0.9 -p 40       makespans: PM vs baselines
//! malltree batch     --trees 200 --threads 8 -p 40       multi-tenant batch throughput
//! malltree simulate  --trees 100 --alpha 0.9 -p 40       Figure 13/14-style rows
//!                    [--faults crash:N@F,... --nodes N]   + fault replay vs restart baseline
//! malltree distribute --grid2d 32 --nodes 4 -p 8
//!                    [--speeds 8,4,4] [--lambda 1.1]
//!                    [--mapping pm|prop|cp]              N-node mapping + cross-node DES
//!                    [--net LAT:BW]                      priced links + comm-avoiding candidate
//!                    [--link-faults linkslow:A:B:X:D@F,..]
//!                    [--timeout-factor T] [--recovery best|wait]
//! malltree factorize --grid2d 24 [--workers 4] [--malleable]
//!                    [--matrix FILE.mtx]                 (alias of --mtx)
//!                    [--block N] [--simd auto|off|force] kernel tile size + ISA dispatch
//!                    [--mem-cap WORDS]
//!                    [--fault-plan task:ID:F|every:K:F]
//!                    [--elastic ±N@C,...] [--retries N]  self-healing malleable crew
//!                    [--backend blocked|naive|pjrt]      numeric factorization + residual
//! malltree memory    --grid2d 32 [--order liu|default]
//!                    [--cap WORDS | --cap-ratio R]
//!                    [--pareto [N]]                      memory-aware planning + Pareto front
//! malltree serve     --arrivals poisson:2 --tenants 4
//!                    [--policy fair|makespan] [--admit Q]
//!                    [--deadline-ratio R]
//!                    [--overload reject|defer|degrade]   online multi-tenant service replay
//! malltree calibrate --grid2d 24 [--workers-sweep 2,4,8]
//!                    [--trace-out FILE.json]             fit alpha from the system's own spans
//! malltree kernelsim --kind cholesky --n 20000 --b 256   Figure 2-6-style T(p) curve
//! malltree dataset   --out DIR --trees 600               write the workload corpus
//! malltree figures                                       regenerate every paper table/figure
//! ```

mod args;
mod commands;

pub use args::Args;

/// Entry point used by `main.rs`.
pub fn run(argv: Vec<String>) -> anyhow::Result<()> {
    let mut args = Args::new(argv);
    let Some(cmd) = args.next_positional() else {
        print!("{}", usage());
        return Ok(());
    };
    match cmd.as_str() {
        "analyze" => commands::analyze(&mut args),
        "schedule" => commands::schedule(&mut args),
        "batch" => commands::batch(&mut args),
        "simulate" => commands::simulate(&mut args),
        "distribute" => commands::distribute(&mut args),
        "factorize" => commands::factorize(&mut args),
        "memory" => commands::memory(&mut args),
        "serve" => commands::serve(&mut args),
        "calibrate" => commands::calibrate(&mut args),
        "kernelsim" => commands::kernelsim(&mut args),
        "dataset" => commands::dataset(&mut args),
        "figures" => commands::figures(&mut args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?}\n{}", usage()),
    }
}

fn usage() -> String {
    "malltree — scheduling trees of malleable tasks for sparse linear algebra\n\
     \n\
     commands:\n\
     \x20 analyze    symbolic analysis of a sparse problem (tree shape summary)\n\
     \x20 schedule   compare PM / Proportional / Divisible makespans on one tree\n\
     \x20 batch      schedule a corpus of independent trees on a thread pool\n\
     \x20 simulate   Figure 13/14 rows over a generated tree corpus\n\
     \x20 distribute map a tree onto N multicore nodes (Alg 11/12) + cross-node DES\n\
     \x20 factorize  end-to-end numeric multifrontal factorization\n\
     \x20 memory     memory-aware planning: Liu traversal, caps, Pareto front\n\
     \x20 serve      online multi-tenant service: arrivals, admission, deadlines\n\
     \x20 calibrate  fit alpha + a drift report from traced factorizations\n\
     \x20 kernelsim  Figure 2-6 kernel timing curves + alpha fit\n\
     \x20 dataset    write the workload corpus to disk\n\
     \x20 figures    regenerate every paper table/figure (see benches for timing)\n\
     \n\
     common flags: --grid2d K | --grid3d K | --mtx FILE | --tree FILE,\n\
     \x20 --alpha A, -p N, --amalgamate W, --seed S, --workers N,\n\
     \x20 --profile d:p[,d:p...] (step processor profile, schedule/simulate),\n\
     \x20 --malleable (schedule-share-driven worker teams per front),\n\
     \x20 --mem-cap WORDS (malleable memory admission gate),\n\
     \x20 --fault-plan task:ID:F|every:K:F (inject F transient failures; with\n\
     \x20   --retries N --backoff-ms MS the crew retries and self-heals),\n\
     \x20 --elastic \u{b1}N@C[,..] (crew grows/shrinks by N after C completions),\n\
     \x20 simulate: --faults crash:N@F|leave:N:C@F|join:N:C@F|slow:N:X:D@F\n\
     \x20   (F,D are fractions of the fault-free makespan) --nodes N\n\
     \x20   --node-cores P --fault-trees K (replay vs remap/restart baselines),\n\
     \x20 --backend blocked|naive|pjrt (--pjrt is an alias),\n\
     \x20 factorize: --matrix FILE.mtx (alias of --mtx), --block N (tile edge,\n\
     \x20   8..=1024), --simd auto|off|force (SIMD microkernel dispatch; the\n\
     \x20   run prints the ISA actually dispatched),\n\
     \x20 --trace-out FILE.json (factorize/simulate/calibrate: export the span\n\
     \x20   timeline as a Chrome trace; MALLTREE_TRACE=on|off overrides),\n\
     \x20 calibrate: --workers-sweep W0,W1,.. (traced team sizes to fit from),\n\
     \x20 distribute: --nodes N -p CORES | --speeds P0,P1,.. (heterogeneous),\n\
     \x20 --lambda L (Alg 12 approximation parameter), --mapping pm|prop|cp,\n\
     \x20 --net LAT:BW (price cross-node transfers; BW may be inf),\n\
     \x20 --link-faults linkslow:A:B:X:D@F|linkdown:A:B:D@F (F,D fractions of\n\
     \x20   the fault-free networked makespan), --timeout-factor T,\n\
     \x20 --recovery best|wait (re-map blocked subtrees vs ride faults out),\n\
     \x20 memory: --order liu|default, --cap WORDS | --cap-ratio R, --pareto [N],\n\
     \x20 serve: --arrivals poisson:RATE|bursty:RATE:B|heavy:RATE:S|trace:FILE,\n\
     \x20   --jobs N --tenants K --policy fair|makespan --admit QUEUE\n\
     \x20   --deadline-ratio R --overload reject|defer|degrade\n\
     \x20   --retries N --backoff F --degrade-factor F\n"
        .to_string()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unknown_command_errors() {
        assert!(super::run(vec!["frobnicate".into()]).is_err());
    }

    #[test]
    fn no_command_prints_usage() {
        super::run(vec![]).unwrap();
    }
}
