//! Dependency-free text format for task trees.
//!
//! ```text
//! # malltree tree v1
//! <n>
//! <parent_0> <len_0>
//! ...
//! ```
//! `parent_i == i` marks the root. The v2 extension appends the
//! per-task memory weights of [`crate::mem::MemWeights`]:
//!
//! ```text
//! # malltree tree v2 (parent len front cb)
//! <n>
//! <parent_0> <len_0> <front_0> <cb_0>
//! ...
//! ```
//! Column counts must be consistent across lines; v1 readers
//! ([`parse_tree`]) accept v2 files and ignore the weights.
//!
//! The v3 extension appends an optional *disturbance section* after
//! the node lines (DESIGN.md §13): a single-integer event count, then
//! one `time kind node [args]` line per event of a
//! [`crate::model::FaultTrace`]:
//!
//! ```text
//! # malltree tree v3 (parent len [front cb]; time kind node [args])
//! <n>
//! <parent_0> <len_0> [...]
//! ...
//! <k>
//! <time> crash <node>
//! <time> leave <node> <cores>
//! <time> join <node> <cores>
//! <time> slow <node> <factor> <duration>
//! ```
//!
//! v1/v2 readers ([`parse_tree`], [`parse_tree_mem`]) accept v3 files
//! and drop the disturbances. Deterministic float formatting keeps
//! traces diff-stable across runs.
//!
//! The v4 extension is a *multi-job* format for the online service
//! (DESIGN.md §14): a `jobs <j>` header, then per job one metadata
//! line `tenant arrival priority deadline` (deadline `inf` = none)
//! followed by a v1/v2-style tree block:
//!
//! ```text
//! # malltree jobs v4 (tenant arrival priority deadline; tree blocks)
//! jobs <j>
//! <tenant> <arrival> <priority> <deadline>
//! <n>
//! <parent_0> <len_0> [front cb]
//! ...
//! ```
//!
//! v1–v3 readers reject v4 files with a typed error (the `jobs`
//! header is not a node count); [`parse_jobs`] rejects v1–v3 files the
//! same way. Every reader is hardened against malformed input —
//! truncated records, negative weights and out-of-range node ids
//! return errors, never panic (property-tested on mutated byte
//! streams).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::mem::MemWeights;
use crate::model::{FaultEvent, FaultKind, FaultTrace, TaskTree};

/// Write `tree` to `path` (v1: no memory weights).
pub fn write_tree(tree: &TaskTree, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# malltree tree v1")?;
    writeln!(w, "{}", tree.len())?;
    for (i, node) in tree.nodes.iter().enumerate() {
        let parent = node.parent.map(|p| p as usize).unwrap_or(i);
        writeln!(w, "{} {:e}", parent, node.len)?;
    }
    Ok(())
}

/// Write `tree` with its per-task memory weights to `path` (v2).
pub fn write_tree_mem(tree: &TaskTree, mem: &MemWeights, path: &Path) -> Result<()> {
    mem.validate(tree)?;
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# malltree tree v2 (parent len front cb)")?;
    writeln!(w, "{}", tree.len())?;
    for (i, node) in tree.nodes.iter().enumerate() {
        let parent = node.parent.map(|p| p as usize).unwrap_or(i);
        writeln!(
            w,
            "{} {:e} {:e} {:e}",
            parent, node.len, mem.front[i], mem.cb[i]
        )?;
    }
    Ok(())
}

/// Write `tree` — with optional memory weights — plus a disturbance
/// trace to `path` (v3).
pub fn write_tree_faults(
    tree: &TaskTree,
    mem: Option<&MemWeights>,
    faults: &FaultTrace,
    path: &Path,
) -> Result<()> {
    if let Some(m) = mem {
        m.validate(tree)?;
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# malltree tree v3 (parent len [front cb]; time kind node [args])")?;
    writeln!(w, "{}", tree.len())?;
    for (i, node) in tree.nodes.iter().enumerate() {
        let parent = node.parent.map(|p| p as usize).unwrap_or(i);
        match mem {
            Some(m) => {
                writeln!(w, "{} {:e} {:e} {:e}", parent, node.len, m.front[i], m.cb[i])?
            }
            None => writeln!(w, "{} {:e}", parent, node.len)?,
        }
    }
    writeln!(w, "{}", faults.len())?;
    for e in &faults.events {
        match e.kind {
            FaultKind::Crash { node } => writeln!(w, "{:e} crash {node}", e.time)?,
            FaultKind::Leave { node, cores } => {
                writeln!(w, "{:e} leave {node} {cores:e}", e.time)?
            }
            FaultKind::Join { node, cores } => writeln!(w, "{:e} join {node} {cores:e}", e.time)?,
            FaultKind::Slowdown { node, factor, duration } => {
                writeln!(w, "{:e} slow {node} {factor:e} {duration:e}", e.time)?
            }
            FaultKind::LinkDegrade { a, b, factor, duration } => {
                writeln!(w, "{:e} linkslow {a} {b} {factor:e} {duration:e}", e.time)?
            }
            FaultKind::LinkDown { a, b, duration } => {
                writeln!(w, "{:e} linkdown {a} {b} {duration:e}", e.time)?
            }
        }
    }
    Ok(())
}

/// Read a tree from `path`, ignoring memory weights if present.
pub fn read_tree(path: &Path) -> Result<TaskTree> {
    read_tree_mem(path).map(|(t, _)| t)
}

/// Read a tree and, when the trace is v2, its memory weights.
pub fn read_tree_mem(path: &Path) -> Result<(TaskTree, Option<MemWeights>)> {
    read_tree_faults(path).map(|(t, m, _)| (t, m))
}

/// Read a tree with memory weights (v2+) and disturbance trace (v3)
/// when present.
pub fn read_tree_faults(
    path: &Path,
) -> Result<(TaskTree, Option<MemWeights>, Option<FaultTrace>)> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    parse_tree_full(std::io::BufReader::new(f))
}

/// Parse the trace format from any reader, ignoring memory weights.
pub fn parse_tree<R: BufRead>(reader: R) -> Result<TaskTree> {
    parse_tree_mem(reader).map(|(t, _)| t)
}

/// Parse the trace format, returning memory weights for v2 traces
/// (`None` for v1) and dropping any v3 disturbance section. Column
/// counts must be consistent across lines.
pub fn parse_tree_mem<R: BufRead>(reader: R) -> Result<(TaskTree, Option<MemWeights>)> {
    parse_tree_full(reader).map(|(t, m, _)| (t, m))
}

/// Preallocation cap for parsed counts: a malformed count like
/// `999999999999` must produce a clean error from the missing lines
/// that follow, not an allocation abort.
const MAX_PREALLOC: usize = 1 << 16;

/// Content lines of a trace: comments and blanks dropped, I/O errors
/// passed through.
fn content_lines<R: BufRead>(reader: R) -> impl Iterator<Item = Result<String>> {
    reader
        .lines()
        .map(|l| l.map_err(anyhow::Error::from))
        .filter(|l| match l {
            Ok(s) => !s.trim().is_empty() && !s.trim_start().starts_with('#'),
            Err(_) => true,
        })
}

/// Parse one `<n>` + node-lines tree block off `lines` — the shared
/// hardened core of the v1–v4 readers. Out-of-range parents, multiple
/// roots and cycles are rejected by [`TaskTree::from_parents`];
/// negative or non-finite lengths and weights are rejected here.
fn read_tree_block<I: Iterator<Item = Result<String>>>(
    lines: &mut I,
) -> Result<(TaskTree, Option<MemWeights>)> {
    let n: usize = lines
        .next()
        .context("missing node count")??
        .trim()
        .parse()
        .context("bad node count")?;
    let mut parents = Vec::with_capacity(n.min(MAX_PREALLOC));
    let mut lens = Vec::with_capacity(n.min(MAX_PREALLOC));
    let mut front = Vec::new();
    let mut cb = Vec::new();
    let mut has_mem: Option<bool> = None;
    for i in 0..n {
        let line = lines
            .next()
            .with_context(|| format!("missing node line {i}"))??;
        let mut it = line.split_whitespace();
        let parent: usize = it
            .next()
            .context("missing parent")?
            .parse()
            .with_context(|| format!("bad parent, node {i}"))?;
        let len: f64 = it
            .next()
            .with_context(|| format!("node {i}: missing length"))?
            .parse()
            .with_context(|| format!("bad length, node {i}"))?;
        ensure!(
            len.is_finite() && len >= 0.0,
            "node {i}: task length must be finite and >= 0 (got {len})"
        );
        parents.push(parent);
        lens.push(len);
        let mem_cols = match (it.next(), it.next()) {
            (None, _) => false,
            (Some(f), Some(c)) => {
                front.push(f.parse::<f64>().with_context(|| format!("bad front, node {i}"))?);
                cb.push(c.parse::<f64>().with_context(|| format!("bad cb, node {i}"))?);
                true
            }
            (Some(_), None) => bail!("node {i}: expected `parent len [front cb]`"),
        };
        match has_mem {
            None => has_mem = Some(mem_cols),
            Some(h) if h != mem_cols => {
                bail!("node {i}: inconsistent column count (mixed v1/v2 lines)")
            }
            _ => {}
        }
        if it.next().is_some() {
            bail!("node {i}: trailing columns beyond `parent len front cb`");
        }
    }
    let tree = TaskTree::from_parents(&parents, &lens)?;
    let mem = if has_mem == Some(true) {
        let m = MemWeights { front, cb };
        m.validate(&tree)?;
        Some(m)
    } else {
        None
    };
    Ok((tree, mem))
}

/// Parse the full trace format: tree, optional memory weights (v2),
/// optional disturbance section (v3).
pub fn parse_tree_full<R: BufRead>(
    reader: R,
) -> Result<(TaskTree, Option<MemWeights>, Option<FaultTrace>)> {
    let mut lines = content_lines(reader);
    let (tree, mem) = read_tree_block(&mut lines)?;
    let n = tree.len();
    // optional v3 disturbance section: a single-integer event count,
    // then `time kind node [args]` lines — anything else is garbage
    let faults = match lines.next() {
        None => None,
        Some(line) => {
            let line = line?;
            let k: usize = match line.trim().parse() {
                Ok(k) => k,
                Err(_) => bail!("trailing data after {n} nodes"),
            };
            let mut events = Vec::with_capacity(k.min(MAX_PREALLOC));
            for i in 0..k {
                let l = lines
                    .next()
                    .with_context(|| format!("missing disturbance line {i}"))??;
                let toks: Vec<&str> = l.split_whitespace().collect();
                let [time, kind, node, args @ ..] = toks.as_slice() else {
                    bail!("disturbance {i}: expected `time kind node [args]`");
                };
                let time: f64 = time
                    .parse()
                    .with_context(|| format!("bad time, disturbance {i}"))?;
                let node: usize = node
                    .parse()
                    .with_context(|| format!("bad node, disturbance {i}"))?;
                let farg = |j: usize, what: &str| -> Result<f64> {
                    args.get(j)
                        .with_context(|| format!("disturbance {i}: missing {what}"))?
                        .parse::<f64>()
                        .with_context(|| format!("bad {what}, disturbance {i}"))
                };
                // link events reuse the `node` column as endpoint `a`;
                // the peer endpoint is the first argument
                let iarg = |j: usize, what: &str| -> Result<usize> {
                    args.get(j)
                        .with_context(|| format!("disturbance {i}: missing {what}"))?
                        .parse::<usize>()
                        .with_context(|| format!("bad {what}, disturbance {i}"))
                };
                let (kind, used) = match *kind {
                    "crash" => (FaultKind::Crash { node }, 0),
                    "leave" => (FaultKind::Leave { node, cores: farg(0, "cores")? }, 1),
                    "join" => (FaultKind::Join { node, cores: farg(0, "cores")? }, 1),
                    "slow" => (
                        FaultKind::Slowdown {
                            node,
                            factor: farg(0, "factor")?,
                            duration: farg(1, "duration")?,
                        },
                        2,
                    ),
                    "linkslow" => (
                        FaultKind::LinkDegrade {
                            a: node,
                            b: iarg(0, "peer")?,
                            factor: farg(1, "factor")?,
                            duration: farg(2, "duration")?,
                        },
                        3,
                    ),
                    "linkdown" => (
                        FaultKind::LinkDown {
                            a: node,
                            b: iarg(0, "peer")?,
                            duration: farg(1, "duration")?,
                        },
                        2,
                    ),
                    other => bail!("disturbance {i}: unknown kind {other:?}"),
                };
                if args.len() > used {
                    bail!("disturbance {i}: trailing columns");
                }
                events.push(FaultEvent { time, kind });
            }
            if lines.next().is_some() {
                bail!("trailing data after {k} disturbance events");
            }
            Some(FaultTrace::new(events))
        }
    };
    Ok((tree, mem, faults))
}

/// One job of a v4 multi-job trace: scheduling metadata plus the task
/// tree itself. `deadline` is an absolute completion time
/// (`f64::INFINITY` = no deadline). Per-task memory weights inside a
/// job's tree block are accepted on read and dropped (the online
/// service does not consume them yet).
#[derive(Debug, Clone)]
pub struct TraceJob {
    /// Owning tenant id.
    pub tenant: usize,
    /// Absolute submission time.
    pub arrival: f64,
    /// Scheduling weight (> 0; higher = more share under weighted-fair
    /// modes).
    pub priority: f64,
    /// Absolute completion deadline; `f64::INFINITY` means none.
    pub deadline: f64,
    /// The malleable task tree the job schedules.
    pub tree: TaskTree,
}

fn validate_job_meta(i: usize, tenant: usize, arrival: f64, priority: f64, deadline: f64) -> Result<()> {
    let _ = tenant;
    ensure!(
        arrival.is_finite() && arrival >= 0.0,
        "job {i}: arrival must be finite and >= 0 (got {arrival})"
    );
    ensure!(
        priority.is_finite() && priority > 0.0,
        "job {i}: priority must be finite and > 0 (got {priority})"
    );
    ensure!(
        !deadline.is_nan() && deadline > arrival,
        "job {i}: deadline must be > arrival or inf (got {deadline})"
    );
    Ok(())
}

/// Write a multi-job arrival trace to `path` (v4).
pub fn write_jobs(jobs: &[TraceJob], path: &Path) -> Result<()> {
    for (i, j) in jobs.iter().enumerate() {
        validate_job_meta(i, j.tenant, j.arrival, j.priority, j.deadline)?;
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# malltree jobs v4 (tenant arrival priority deadline; one tree block per job)")?;
    writeln!(w, "jobs {}", jobs.len())?;
    for j in jobs {
        writeln!(w, "{} {:e} {:e} {:e}", j.tenant, j.arrival, j.priority, j.deadline)?;
        writeln!(w, "{}", j.tree.len())?;
        for (i, node) in j.tree.nodes.iter().enumerate() {
            let parent = node.parent.map(|p| p as usize).unwrap_or(i);
            writeln!(w, "{} {:e}", parent, node.len)?;
        }
    }
    Ok(())
}

/// Read a v4 multi-job arrival trace from `path`.
pub fn read_jobs(path: &Path) -> Result<Vec<TraceJob>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    parse_jobs(std::io::BufReader::new(f))
}

/// Parse a v4 multi-job trace from any reader. v1–v3 single-tree
/// traces are rejected with a typed error (their first content line is
/// a node count, not the `jobs <j>` header).
pub fn parse_jobs<R: BufRead>(reader: R) -> Result<Vec<TraceJob>> {
    let mut lines = content_lines(reader);
    let header = lines.next().context("empty jobs trace")??;
    let j: usize = header
        .trim()
        .strip_prefix("jobs")
        .context("not a v4 jobs trace (want a `jobs <count>` header line)")?
        .trim()
        .parse()
        .context("bad job count")?;
    let mut jobs = Vec::with_capacity(j.min(MAX_PREALLOC));
    for i in 0..j {
        let meta = lines
            .next()
            .with_context(|| format!("missing metadata line for job {i}"))??;
        let toks: Vec<&str> = meta.split_whitespace().collect();
        let [tenant, arrival, priority, deadline] = toks.as_slice() else {
            bail!("job {i}: expected `tenant arrival priority deadline`, got {meta:?}");
        };
        let tenant: usize = tenant
            .parse()
            .with_context(|| format!("bad tenant, job {i}"))?;
        let arrival: f64 = arrival
            .parse()
            .with_context(|| format!("bad arrival, job {i}"))?;
        let priority: f64 = priority
            .parse()
            .with_context(|| format!("bad priority, job {i}"))?;
        let deadline: f64 = deadline
            .parse()
            .with_context(|| format!("bad deadline, job {i}"))?;
        validate_job_meta(i, tenant, arrival, priority, deadline)?;
        let (tree, _mem) = read_tree_block(&mut lines)
            .with_context(|| format!("reading the tree block of job {i}"))?;
        jobs.push(TraceJob { tenant, arrival, priority, deadline, tree });
    }
    if lines.next().is_some() {
        bail!("trailing data after {j} jobs");
    }
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;
    use crate::workload::generator::{random_tree, synthetic_mem_weights, TreeClass};
    use std::io::Cursor;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("malltree_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip() {
        let t = TaskTree::from_parents(&[0, 0, 0, 1], &[1.5, 2.25, 0.001, 1e9]).unwrap();
        let path = tmp("t.tree");
        write_tree(&t, &path).unwrap();
        let back = read_tree(&path).unwrap();
        assert_eq!(back.len(), 4);
        for (a, b) in t.nodes.iter().zip(&back.nodes) {
            assert_eq!(a.parent, b.parent);
            assert!((a.len - b.len).abs() <= 1e-12 * a.len.abs().max(1.0));
        }
    }

    #[test]
    fn round_trip_randomized_v1_and_v2() {
        // the satellite property: write → parse recovers structure,
        // lengths and (v2) memory weights across random trees
        check(
            Config { cases: 12, seed: 0x77ACE },
            "trace round-trip (v1 + v2)",
            |rng: &mut Rng| {
                let classes = [TreeClass::Uniform, TreeClass::Deep, TreeClass::Binary];
                let t = random_tree(classes[rng.below(3)], rng.range(2, 200), rng);
                let w = synthetic_mem_weights(&t, rng);
                let tag = rng.next_u64();
                (t, w, tag)
            },
            |(t, w, tag)| {
                let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 * a.abs().max(1.0);
                // v1
                let p1 = tmp(&format!("prop_v1_{tag}.tree"));
                write_tree(t, &p1).map_err(|e| e.to_string())?;
                let (t1, m1) = read_tree_mem(&p1).map_err(|e| e.to_string())?;
                if m1.is_some() {
                    return Err("v1 trace produced weights".into());
                }
                // v2
                let p2 = tmp(&format!("prop_v2_{tag}.tree"));
                write_tree_mem(t, w, &p2).map_err(|e| e.to_string())?;
                let (t2, m2) = read_tree_mem(&p2).map_err(|e| e.to_string())?;
                let m2 = m2.ok_or("v2 trace lost its weights")?;
                for (back, orig) in [(&t1, t), (&t2, t)] {
                    if back.len() != orig.len() {
                        return Err("node count changed".into());
                    }
                    for (a, b) in back.nodes.iter().zip(&orig.nodes) {
                        if a.parent != b.parent || !close(a.len, b.len) {
                            return Err("structure or length changed".into());
                        }
                    }
                }
                for i in 0..t.len() {
                    if !close(m2.front[i], w.front[i]) || !close(m2.cb[i], w.cb[i]) {
                        return Err(format!("weights changed at task {i}"));
                    }
                }
                // v1 readers accept v2 files
                let t2v1 = read_tree(&p2).map_err(|e| e.to_string())?;
                if t2v1.len() != t.len() {
                    return Err("v1 reader rejected v2 trace".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn parses_with_comments() {
        let text = "# comment\n3\n0 1.0\n# mid comment\n0 2.0\n1 3.0\n";
        let t = parse_tree(Cursor::new(text)).unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.nodes[2].parent, Some(1));
    }

    #[test]
    fn parses_v2_weights() {
        let text = "# malltree tree v2 (parent len front cb)\n2\n0 1.0 16.0 4.0\n0 2.0 9.0 1.0\n";
        let (t, m) = parse_tree_mem(Cursor::new(text)).unwrap();
        assert_eq!(t.len(), 2);
        let m = m.unwrap();
        assert_eq!(m.front, vec![16.0, 9.0]);
        assert_eq!(m.cb, vec![4.0, 1.0]);
    }

    #[test]
    fn rejects_mixed_column_counts() {
        let text = "2\n0 1.0 16.0 4.0\n0 2.0\n";
        assert!(parse_tree_mem(Cursor::new(text)).is_err());
    }

    #[test]
    fn rejects_three_column_lines() {
        let text = "1\n0 1.0 16.0\n";
        assert!(parse_tree_mem(Cursor::new(text)).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let text = "2\n0 1.0\n0 2.0\n0 3.0\n";
        assert!(parse_tree(Cursor::new(text)).is_err());
    }

    #[test]
    fn v3_round_trip_with_and_without_weights() {
        let t = TaskTree::from_parents(&[0, 0, 0], &[1.0, 2.0, 3.0]).unwrap();
        // dyadic values so exact equality survives the text format
        let trace = FaultTrace::new(vec![
            FaultEvent { time: 0.5, kind: FaultKind::Crash { node: 1 } },
            FaultEvent { time: 1.25, kind: FaultKind::Leave { node: 0, cores: 2.0 } },
            FaultEvent { time: 2.0, kind: FaultKind::Join { node: 0, cores: 1.0 } },
            FaultEvent {
                time: 3.5,
                kind: FaultKind::Slowdown { node: 2, factor: 0.5, duration: 0.75 },
            },
            FaultEvent {
                time: 4.25,
                kind: FaultKind::LinkDegrade { a: 0, b: 1, factor: 0.25, duration: 1.5 },
            },
            FaultEvent { time: 5.5, kind: FaultKind::LinkDown { a: 1, b: 0, duration: 0.5 } },
        ]);
        let p = tmp("v3_plain.tree");
        write_tree_faults(&t, None, &trace, &p).unwrap();
        let (t2, m2, f2) = read_tree_faults(&p).unwrap();
        assert_eq!(t2.len(), 3);
        assert!(m2.is_none());
        assert_eq!(f2.unwrap(), trace);
        let mut rng = Rng::new(9);
        let w = synthetic_mem_weights(&t, &mut rng);
        let p = tmp("v3_mem.tree");
        write_tree_faults(&t, Some(&w), &trace, &p).unwrap();
        let (_, m3, f3) = read_tree_faults(&p).unwrap();
        assert!(m3.is_some());
        assert_eq!(f3.unwrap(), trace);
        // v1/v2 readers accept v3 files and drop the disturbances
        let (t4, m4) = read_tree_mem(&p).unwrap();
        assert_eq!(t4.len(), 3);
        assert!(m4.is_some());
        assert_eq!(read_tree(&p).unwrap().len(), 3);
    }

    #[test]
    fn rejects_bad_disturbance_sections() {
        for bad in [
            "1\n0 1.0\n2\n5e-1 crash 0\n",          // truncated event list
            "1\n0 1.0\n1\n5e-1 melt 0\n",           // unknown kind
            "1\n0 1.0\n1\n5e-1 leave 0\n",          // missing cores
            "1\n0 1.0\n1\n5e-1 slow 0 5e-1\n",      // missing duration
            "1\n0 1.0\n1\n5e-1 crash 0 7\n",        // trailing columns
            "1\n0 1.0\n1\n5e-1 crash 0\nextra\n",   // data after the events
            "1\n0 1.0\n1\n5e-1 crash zero\n",       // bad node
            "1\n0 1.0\n1\n5e-1 linkslow 0 1 5e-1\n", // missing link duration
            "1\n0 1.0\n1\n5e-1 linkslow 0 one 5e-1 1e0\n", // non-integer peer
            "1\n0 1.0\n1\n5e-1 linkslow 0 1.5 5e-1 1e0\n", // float peer
            "1\n0 1.0\n1\n5e-1 linkdown 0 1\n",     // missing duration
            "1\n0 1.0\n1\n5e-1 linkdown 0 1 1e0 7\n", // trailing columns
        ] {
            assert!(parse_tree_full(Cursor::new(bad)).is_err(), "{bad:?}");
        }
        // an explicit empty disturbance section is fine
        let (_, _, f) = parse_tree_full(Cursor::new("1\n0 1.0\n0\n")).unwrap();
        assert!(f.unwrap().is_empty());
    }

    #[test]
    fn rejects_truncated() {
        let text = "3\n0 1.0\n";
        assert!(parse_tree(Cursor::new(text)).is_err());
    }

    #[test]
    fn rejects_negative_and_non_finite_lengths() {
        for bad in [
            "2\n0 1.0\n0 -2.0\n",   // negative length
            "2\n0 NaN\n0 2.0\n",    // NaN length
            "2\n0 inf\n0 2.0\n",    // infinite length
            "2\n0 1.0 -1.0 0.5\n0 2.0 4.0 1.0\n", // negative front weight
            "2\n0 1.0 4.0 -0.5\n0 2.0 4.0 1.0\n", // negative cb weight
        ] {
            assert!(parse_tree_mem(Cursor::new(bad)).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn rejects_out_of_range_parent_without_panicking() {
        // from_parents turns these into typed errors, not panics
        for bad in ["2\n0 1.0\n9 2.0\n", "2\n1 1.0\n0 2.0\n"] {
            assert!(parse_tree(Cursor::new(bad)).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn huge_counts_error_cleanly_instead_of_aborting() {
        // a lying node/event/job count must hit "missing line", not an
        // allocation abort from with_capacity
        assert!(parse_tree(Cursor::new("999999999999999\n0 1.0\n")).is_err());
        assert!(parse_tree_full(Cursor::new("1\n0 1.0\n999999999999999\n")).is_err());
        assert!(parse_jobs(Cursor::new("jobs 999999999999999\n")).is_err());
    }

    fn v4_jobs(rng: &mut Rng) -> Vec<TraceJob> {
        let classes = [TreeClass::Uniform, TreeClass::Deep, TreeClass::Binary];
        (0..rng.range(1, 6))
            .map(|i| {
                let arrival = i as f64 * rng.range_f64(0.25, 2.0);
                TraceJob {
                    tenant: rng.below(4),
                    arrival,
                    priority: rng.range_f64(0.5, 3.0),
                    deadline: if rng.bool(0.5) {
                        f64::INFINITY
                    } else {
                        arrival + rng.range_f64(1.0, 100.0)
                    },
                    tree: random_tree(classes[rng.below(3)], rng.range(1, 60), rng),
                }
            })
            .collect()
    }

    #[test]
    fn v4_round_trip_randomized() {
        check(
            Config { cases: 12, seed: 0x4B4B },
            "jobs trace round-trip (v4)",
            |rng: &mut Rng| (v4_jobs(rng), rng.next_u64()),
            |(jobs, tag)| {
                let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 * a.abs().max(1.0);
                let p = tmp(&format!("prop_v4_{tag}.jobs"));
                write_jobs(jobs, &p).map_err(|e| e.to_string())?;
                let back = read_jobs(&p).map_err(|e| e.to_string())?;
                if back.len() != jobs.len() {
                    return Err("job count changed".into());
                }
                for (a, b) in back.iter().zip(jobs) {
                    if a.tenant != b.tenant
                        || !close(a.arrival, b.arrival)
                        || !close(a.priority, b.priority)
                        || (a.deadline != b.deadline && !close(a.deadline, b.deadline))
                    {
                        return Err("job metadata changed".into());
                    }
                    if a.tree.len() != b.tree.len() {
                        return Err("tree size changed".into());
                    }
                    for (x, y) in a.tree.nodes.iter().zip(&b.tree.nodes) {
                        if x.parent != y.parent || !close(x.len, y.len) {
                            return Err("tree structure or length changed".into());
                        }
                    }
                }
                // v1–v3 readers reject the v4 file with an error
                if read_tree_faults(&p).is_ok() {
                    return Err("v1-v3 reader accepted a v4 file".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn v4_rejects_malformed_jobs() {
        for bad in [
            "jobs 2\n0 0 1 inf\n1\n0 1.0\n",               // truncated job list
            "jobs 1\n0 0 1\n1\n0 1.0\n",                    // short metadata line
            "jobs 1\n0 0 1 inf extra\n1\n0 1.0\n",          // long metadata line
            "jobs 1\n0 -1 1 inf\n1\n0 1.0\n",               // negative arrival
            "jobs 1\n0 0 0 inf\n1\n0 1.0\n",                // zero priority
            "jobs 1\n0 0 NaN inf\n1\n0 1.0\n",              // NaN priority
            "jobs 1\n0 5 1 2\n1\n0 1.0\n",                  // deadline before arrival
            "jobs 1\n0 0 1 NaN\n1\n0 1.0\n",                // NaN deadline
            "jobs 1\n0 0 1 inf\n1\n0 -1.0\n",               // negative task length
            "jobs 1\n0 0 1 inf\n2\n0 1.0\n",                // truncated tree block
            "jobs 1\n0 0 1 inf\n1\n0 1.0\nextra\n",         // trailing data
            "jobs x\n",                                     // bad job count
            "2\n0 1.0\n0 2.0\n",                            // a v1 trace is not v4
        ] {
            assert!(parse_jobs(Cursor::new(bad)).is_err(), "accepted {bad:?}");
        }
        // an explicitly empty jobs trace is fine
        assert!(parse_jobs(Cursor::new("jobs 0\n")).unwrap().is_empty());
    }

    #[test]
    fn mutated_byte_streams_error_but_never_panic_in_any_reader() {
        // the satellite-b property: take a valid v1/v2/v3/v4 trace,
        // mutate its bytes (truncate / flip / insert), and feed the
        // result to every reader — each must return Ok or Err, never
        // panic or abort (a panic fails this test)
        check(
            Config { cases: 40, seed: 0xF422 },
            "mutated trace bytes never panic a reader",
            |rng: &mut Rng| {
                let t = random_tree(TreeClass::Uniform, rng.range(1, 30), rng);
                let w = synthetic_mem_weights(&t, rng);
                let mut ev = crate::workload::generator::random_fault_trace(2, 10.0, 3, rng).events;
                ev.extend(crate::workload::generator::random_link_fault_trace(2, 10.0, 2, rng).events);
                let faults = FaultTrace::new(ev);
                let tag = rng.next_u64();
                let paths = [
                    tmp(&format!("fuzz_v1_{tag}.tree")),
                    tmp(&format!("fuzz_v2_{tag}.tree")),
                    tmp(&format!("fuzz_v3_{tag}.tree")),
                    tmp(&format!("fuzz_v4_{tag}.jobs")),
                ];
                write_tree(&t, &paths[0]).unwrap();
                write_tree_mem(&t, &w, &paths[1]).unwrap();
                write_tree_faults(&t, Some(&w), &faults, &paths[2]).unwrap();
                let job = TraceJob {
                    tenant: 0,
                    arrival: 0.0,
                    priority: 1.0,
                    deadline: f64::INFINITY,
                    tree: t,
                };
                write_jobs(std::slice::from_ref(&job), &paths[3]).unwrap();
                let mut variants: Vec<Vec<u8>> = Vec::new();
                for p in &paths {
                    let bytes = std::fs::read(p).unwrap();
                    for _ in 0..4 {
                        let mut m = bytes.clone();
                        match rng.below(3) {
                            0 => m.truncate(rng.below(m.len().max(1))),
                            1 => {
                                if !m.is_empty() {
                                    let at = rng.below(m.len());
                                    m[at] = b' ' + rng.below(95) as u8;
                                }
                            }
                            _ => {
                                let at = rng.below(m.len() + 1);
                                m.insert(at, b"-9x\n#"[rng.below(5)]);
                            }
                        }
                        variants.push(m);
                    }
                    variants.push(bytes);
                }
                variants
            },
            |variants| {
                for bytes in variants {
                    // outcomes are unconstrained (a mutation can leave a
                    // trace valid); reaching the end without a panic is
                    // the property
                    let _ = parse_tree(Cursor::new(bytes.as_slice()));
                    let _ = parse_tree_mem(Cursor::new(bytes.as_slice()));
                    let _ = parse_tree_full(Cursor::new(bytes.as_slice()));
                    let _ = parse_jobs(Cursor::new(bytes.as_slice()));
                }
                Ok(())
            },
        );
    }
}
