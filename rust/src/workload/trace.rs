//! Dependency-free text format for task trees.
//!
//! ```text
//! # malltree tree v1
//! <n>
//! <parent_0> <len_0>
//! ...
//! ```
//! `parent_i == i` marks the root. Deterministic float formatting keeps
//! traces diff-stable across runs.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::TaskTree;

/// Write `tree` to `path`.
pub fn write_tree(tree: &TaskTree, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# malltree tree v1")?;
    writeln!(w, "{}", tree.len())?;
    for (i, node) in tree.nodes.iter().enumerate() {
        let parent = node.parent.map(|p| p as usize).unwrap_or(i);
        writeln!(w, "{} {:e}", parent, node.len)?;
    }
    Ok(())
}

/// Read a tree from `path`.
pub fn read_tree(path: &Path) -> Result<TaskTree> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    parse_tree(std::io::BufReader::new(f))
}

/// Parse the trace format from any reader.
pub fn parse_tree<R: BufRead>(reader: R) -> Result<TaskTree> {
    let mut lines = reader
        .lines()
        .map(|l| l.map_err(anyhow::Error::from))
        .filter(|l| match l {
            Ok(s) => !s.trim().is_empty() && !s.trim_start().starts_with('#'),
            Err(_) => true,
        });
    let n: usize = lines
        .next()
        .context("missing node count")??
        .trim()
        .parse()
        .context("bad node count")?;
    let mut parents = Vec::with_capacity(n);
    let mut lens = Vec::with_capacity(n);
    for i in 0..n {
        let line = lines
            .next()
            .with_context(|| format!("missing node line {i}"))??;
        let mut it = line.split_whitespace();
        let parent: usize = it.next().context("missing parent")?.parse()?;
        let len: f64 = it.next().context("missing length")?.parse()?;
        parents.push(parent);
        lens.push(len);
    }
    if lines.next().is_some() {
        bail!("trailing data after {n} nodes");
    }
    TaskTree::from_parents(&parents, &lens)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn round_trip() {
        let t = TaskTree::from_parents(&[0, 0, 0, 1], &[1.5, 2.25, 0.001, 1e9]).unwrap();
        let dir = std::env::temp_dir().join("malltree_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.tree");
        write_tree(&t, &path).unwrap();
        let back = read_tree(&path).unwrap();
        assert_eq!(back.len(), 4);
        for (a, b) in t.nodes.iter().zip(&back.nodes) {
            assert_eq!(a.parent, b.parent);
            assert!((a.len - b.len).abs() <= 1e-12 * a.len.abs().max(1.0));
        }
    }

    #[test]
    fn parses_with_comments() {
        let text = "# comment\n3\n0 1.0\n# mid comment\n0 2.0\n1 3.0\n";
        let t = parse_tree(Cursor::new(text)).unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.nodes[2].parent, Some(1));
    }

    #[test]
    fn rejects_trailing_garbage() {
        let text = "2\n0 1.0\n0 2.0\n0 3.0\n";
        assert!(parse_tree(Cursor::new(text)).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let text = "3\n0 1.0\n";
        assert!(parse_tree(Cursor::new(text)).is_err());
    }
}
