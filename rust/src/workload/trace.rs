//! Dependency-free text format for task trees.
//!
//! ```text
//! # malltree tree v1
//! <n>
//! <parent_0> <len_0>
//! ...
//! ```
//! `parent_i == i` marks the root. The v2 extension appends the
//! per-task memory weights of [`crate::mem::MemWeights`]:
//!
//! ```text
//! # malltree tree v2 (parent len front cb)
//! <n>
//! <parent_0> <len_0> <front_0> <cb_0>
//! ...
//! ```
//! Column counts must be consistent across lines; v1 readers
//! ([`parse_tree`]) accept v2 files and ignore the weights.
//!
//! The v3 extension appends an optional *disturbance section* after
//! the node lines (DESIGN.md §13): a single-integer event count, then
//! one `time kind node [args]` line per event of a
//! [`crate::model::FaultTrace`]:
//!
//! ```text
//! # malltree tree v3 (parent len [front cb]; time kind node [args])
//! <n>
//! <parent_0> <len_0> [...]
//! ...
//! <k>
//! <time> crash <node>
//! <time> leave <node> <cores>
//! <time> join <node> <cores>
//! <time> slow <node> <factor> <duration>
//! ```
//!
//! v1/v2 readers ([`parse_tree`], [`parse_tree_mem`]) accept v3 files
//! and drop the disturbances. Deterministic float formatting keeps
//! traces diff-stable across runs.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::mem::MemWeights;
use crate::model::{FaultEvent, FaultKind, FaultTrace, TaskTree};

/// Write `tree` to `path` (v1: no memory weights).
pub fn write_tree(tree: &TaskTree, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# malltree tree v1")?;
    writeln!(w, "{}", tree.len())?;
    for (i, node) in tree.nodes.iter().enumerate() {
        let parent = node.parent.map(|p| p as usize).unwrap_or(i);
        writeln!(w, "{} {:e}", parent, node.len)?;
    }
    Ok(())
}

/// Write `tree` with its per-task memory weights to `path` (v2).
pub fn write_tree_mem(tree: &TaskTree, mem: &MemWeights, path: &Path) -> Result<()> {
    mem.validate(tree)?;
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# malltree tree v2 (parent len front cb)")?;
    writeln!(w, "{}", tree.len())?;
    for (i, node) in tree.nodes.iter().enumerate() {
        let parent = node.parent.map(|p| p as usize).unwrap_or(i);
        writeln!(
            w,
            "{} {:e} {:e} {:e}",
            parent, node.len, mem.front[i], mem.cb[i]
        )?;
    }
    Ok(())
}

/// Write `tree` — with optional memory weights — plus a disturbance
/// trace to `path` (v3).
pub fn write_tree_faults(
    tree: &TaskTree,
    mem: Option<&MemWeights>,
    faults: &FaultTrace,
    path: &Path,
) -> Result<()> {
    if let Some(m) = mem {
        m.validate(tree)?;
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# malltree tree v3 (parent len [front cb]; time kind node [args])")?;
    writeln!(w, "{}", tree.len())?;
    for (i, node) in tree.nodes.iter().enumerate() {
        let parent = node.parent.map(|p| p as usize).unwrap_or(i);
        match mem {
            Some(m) => {
                writeln!(w, "{} {:e} {:e} {:e}", parent, node.len, m.front[i], m.cb[i])?
            }
            None => writeln!(w, "{} {:e}", parent, node.len)?,
        }
    }
    writeln!(w, "{}", faults.len())?;
    for e in &faults.events {
        match e.kind {
            FaultKind::Crash { node } => writeln!(w, "{:e} crash {node}", e.time)?,
            FaultKind::Leave { node, cores } => {
                writeln!(w, "{:e} leave {node} {cores:e}", e.time)?
            }
            FaultKind::Join { node, cores } => writeln!(w, "{:e} join {node} {cores:e}", e.time)?,
            FaultKind::Slowdown { node, factor, duration } => {
                writeln!(w, "{:e} slow {node} {factor:e} {duration:e}", e.time)?
            }
        }
    }
    Ok(())
}

/// Read a tree from `path`, ignoring memory weights if present.
pub fn read_tree(path: &Path) -> Result<TaskTree> {
    read_tree_mem(path).map(|(t, _)| t)
}

/// Read a tree and, when the trace is v2, its memory weights.
pub fn read_tree_mem(path: &Path) -> Result<(TaskTree, Option<MemWeights>)> {
    read_tree_faults(path).map(|(t, m, _)| (t, m))
}

/// Read a tree with memory weights (v2+) and disturbance trace (v3)
/// when present.
pub fn read_tree_faults(
    path: &Path,
) -> Result<(TaskTree, Option<MemWeights>, Option<FaultTrace>)> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    parse_tree_full(std::io::BufReader::new(f))
}

/// Parse the trace format from any reader, ignoring memory weights.
pub fn parse_tree<R: BufRead>(reader: R) -> Result<TaskTree> {
    parse_tree_mem(reader).map(|(t, _)| t)
}

/// Parse the trace format, returning memory weights for v2 traces
/// (`None` for v1) and dropping any v3 disturbance section. Column
/// counts must be consistent across lines.
pub fn parse_tree_mem<R: BufRead>(reader: R) -> Result<(TaskTree, Option<MemWeights>)> {
    parse_tree_full(reader).map(|(t, m, _)| (t, m))
}

/// Parse the full trace format: tree, optional memory weights (v2),
/// optional disturbance section (v3).
pub fn parse_tree_full<R: BufRead>(
    reader: R,
) -> Result<(TaskTree, Option<MemWeights>, Option<FaultTrace>)> {
    let mut lines = reader
        .lines()
        .map(|l| l.map_err(anyhow::Error::from))
        .filter(|l| match l {
            Ok(s) => !s.trim().is_empty() && !s.trim_start().starts_with('#'),
            Err(_) => true,
        });
    let n: usize = lines
        .next()
        .context("missing node count")??
        .trim()
        .parse()
        .context("bad node count")?;
    let mut parents = Vec::with_capacity(n);
    let mut lens = Vec::with_capacity(n);
    let mut front = Vec::with_capacity(n);
    let mut cb = Vec::with_capacity(n);
    let mut has_mem: Option<bool> = None;
    for i in 0..n {
        let line = lines
            .next()
            .with_context(|| format!("missing node line {i}"))??;
        let mut it = line.split_whitespace();
        let parent: usize = it.next().context("missing parent")?.parse()?;
        let len: f64 = it.next().context("missing length")?.parse()?;
        parents.push(parent);
        lens.push(len);
        let mem_cols = match (it.next(), it.next()) {
            (None, _) => false,
            (Some(f), Some(c)) => {
                front.push(f.parse::<f64>().with_context(|| format!("bad front, node {i}"))?);
                cb.push(c.parse::<f64>().with_context(|| format!("bad cb, node {i}"))?);
                true
            }
            (Some(_), None) => bail!("node {i}: expected `parent len [front cb]`"),
        };
        match has_mem {
            None => has_mem = Some(mem_cols),
            Some(h) if h != mem_cols => {
                bail!("node {i}: inconsistent column count (mixed v1/v2 lines)")
            }
            _ => {}
        }
        if it.next().is_some() {
            bail!("node {i}: trailing columns beyond `parent len front cb`");
        }
    }
    // optional v3 disturbance section: a single-integer event count,
    // then `time kind node [args]` lines — anything else is garbage
    let faults = match lines.next() {
        None => None,
        Some(line) => {
            let line = line?;
            let k: usize = match line.trim().parse() {
                Ok(k) => k,
                Err(_) => bail!("trailing data after {n} nodes"),
            };
            let mut events = Vec::with_capacity(k);
            for i in 0..k {
                let l = lines
                    .next()
                    .with_context(|| format!("missing disturbance line {i}"))??;
                let toks: Vec<&str> = l.split_whitespace().collect();
                let [time, kind, node, args @ ..] = toks.as_slice() else {
                    bail!("disturbance {i}: expected `time kind node [args]`");
                };
                let time: f64 = time
                    .parse()
                    .with_context(|| format!("bad time, disturbance {i}"))?;
                let node: usize = node
                    .parse()
                    .with_context(|| format!("bad node, disturbance {i}"))?;
                let farg = |j: usize, what: &str| -> Result<f64> {
                    args.get(j)
                        .with_context(|| format!("disturbance {i}: missing {what}"))?
                        .parse::<f64>()
                        .with_context(|| format!("bad {what}, disturbance {i}"))
                };
                let (kind, used) = match *kind {
                    "crash" => (FaultKind::Crash { node }, 0),
                    "leave" => (FaultKind::Leave { node, cores: farg(0, "cores")? }, 1),
                    "join" => (FaultKind::Join { node, cores: farg(0, "cores")? }, 1),
                    "slow" => (
                        FaultKind::Slowdown {
                            node,
                            factor: farg(0, "factor")?,
                            duration: farg(1, "duration")?,
                        },
                        2,
                    ),
                    other => bail!("disturbance {i}: unknown kind {other:?}"),
                };
                if args.len() > used {
                    bail!("disturbance {i}: trailing columns");
                }
                events.push(FaultEvent { time, kind });
            }
            if lines.next().is_some() {
                bail!("trailing data after {k} disturbance events");
            }
            Some(FaultTrace::new(events))
        }
    };
    let tree = TaskTree::from_parents(&parents, &lens)?;
    let mem = if has_mem == Some(true) {
        let m = MemWeights { front, cb };
        m.validate(&tree)?;
        Some(m)
    } else {
        None
    };
    Ok((tree, mem, faults))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;
    use crate::workload::generator::{random_tree, synthetic_mem_weights, TreeClass};
    use std::io::Cursor;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("malltree_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip() {
        let t = TaskTree::from_parents(&[0, 0, 0, 1], &[1.5, 2.25, 0.001, 1e9]).unwrap();
        let path = tmp("t.tree");
        write_tree(&t, &path).unwrap();
        let back = read_tree(&path).unwrap();
        assert_eq!(back.len(), 4);
        for (a, b) in t.nodes.iter().zip(&back.nodes) {
            assert_eq!(a.parent, b.parent);
            assert!((a.len - b.len).abs() <= 1e-12 * a.len.abs().max(1.0));
        }
    }

    #[test]
    fn round_trip_randomized_v1_and_v2() {
        // the satellite property: write → parse recovers structure,
        // lengths and (v2) memory weights across random trees
        check(
            Config { cases: 12, seed: 0x77ACE },
            "trace round-trip (v1 + v2)",
            |rng: &mut Rng| {
                let classes = [TreeClass::Uniform, TreeClass::Deep, TreeClass::Binary];
                let t = random_tree(classes[rng.below(3)], rng.range(2, 200), rng);
                let w = synthetic_mem_weights(&t, rng);
                let tag = rng.next_u64();
                (t, w, tag)
            },
            |(t, w, tag)| {
                let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 * a.abs().max(1.0);
                // v1
                let p1 = tmp(&format!("prop_v1_{tag}.tree"));
                write_tree(t, &p1).map_err(|e| e.to_string())?;
                let (t1, m1) = read_tree_mem(&p1).map_err(|e| e.to_string())?;
                if m1.is_some() {
                    return Err("v1 trace produced weights".into());
                }
                // v2
                let p2 = tmp(&format!("prop_v2_{tag}.tree"));
                write_tree_mem(t, w, &p2).map_err(|e| e.to_string())?;
                let (t2, m2) = read_tree_mem(&p2).map_err(|e| e.to_string())?;
                let m2 = m2.ok_or("v2 trace lost its weights")?;
                for (back, orig) in [(&t1, t), (&t2, t)] {
                    if back.len() != orig.len() {
                        return Err("node count changed".into());
                    }
                    for (a, b) in back.nodes.iter().zip(&orig.nodes) {
                        if a.parent != b.parent || !close(a.len, b.len) {
                            return Err("structure or length changed".into());
                        }
                    }
                }
                for i in 0..t.len() {
                    if !close(m2.front[i], w.front[i]) || !close(m2.cb[i], w.cb[i]) {
                        return Err(format!("weights changed at task {i}"));
                    }
                }
                // v1 readers accept v2 files
                let t2v1 = read_tree(&p2).map_err(|e| e.to_string())?;
                if t2v1.len() != t.len() {
                    return Err("v1 reader rejected v2 trace".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn parses_with_comments() {
        let text = "# comment\n3\n0 1.0\n# mid comment\n0 2.0\n1 3.0\n";
        let t = parse_tree(Cursor::new(text)).unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.nodes[2].parent, Some(1));
    }

    #[test]
    fn parses_v2_weights() {
        let text = "# malltree tree v2 (parent len front cb)\n2\n0 1.0 16.0 4.0\n0 2.0 9.0 1.0\n";
        let (t, m) = parse_tree_mem(Cursor::new(text)).unwrap();
        assert_eq!(t.len(), 2);
        let m = m.unwrap();
        assert_eq!(m.front, vec![16.0, 9.0]);
        assert_eq!(m.cb, vec![4.0, 1.0]);
    }

    #[test]
    fn rejects_mixed_column_counts() {
        let text = "2\n0 1.0 16.0 4.0\n0 2.0\n";
        assert!(parse_tree_mem(Cursor::new(text)).is_err());
    }

    #[test]
    fn rejects_three_column_lines() {
        let text = "1\n0 1.0 16.0\n";
        assert!(parse_tree_mem(Cursor::new(text)).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let text = "2\n0 1.0\n0 2.0\n0 3.0\n";
        assert!(parse_tree(Cursor::new(text)).is_err());
    }

    #[test]
    fn v3_round_trip_with_and_without_weights() {
        let t = TaskTree::from_parents(&[0, 0, 0], &[1.0, 2.0, 3.0]).unwrap();
        // dyadic values so exact equality survives the text format
        let trace = FaultTrace::new(vec![
            FaultEvent { time: 0.5, kind: FaultKind::Crash { node: 1 } },
            FaultEvent { time: 1.25, kind: FaultKind::Leave { node: 0, cores: 2.0 } },
            FaultEvent { time: 2.0, kind: FaultKind::Join { node: 0, cores: 1.0 } },
            FaultEvent {
                time: 3.5,
                kind: FaultKind::Slowdown { node: 2, factor: 0.5, duration: 0.75 },
            },
        ]);
        let p = tmp("v3_plain.tree");
        write_tree_faults(&t, None, &trace, &p).unwrap();
        let (t2, m2, f2) = read_tree_faults(&p).unwrap();
        assert_eq!(t2.len(), 3);
        assert!(m2.is_none());
        assert_eq!(f2.unwrap(), trace);
        let mut rng = Rng::new(9);
        let w = synthetic_mem_weights(&t, &mut rng);
        let p = tmp("v3_mem.tree");
        write_tree_faults(&t, Some(&w), &trace, &p).unwrap();
        let (_, m3, f3) = read_tree_faults(&p).unwrap();
        assert!(m3.is_some());
        assert_eq!(f3.unwrap(), trace);
        // v1/v2 readers accept v3 files and drop the disturbances
        let (t4, m4) = read_tree_mem(&p).unwrap();
        assert_eq!(t4.len(), 3);
        assert!(m4.is_some());
        assert_eq!(read_tree(&p).unwrap().len(), 3);
    }

    #[test]
    fn rejects_bad_disturbance_sections() {
        for bad in [
            "1\n0 1.0\n2\n5e-1 crash 0\n",          // truncated event list
            "1\n0 1.0\n1\n5e-1 melt 0\n",           // unknown kind
            "1\n0 1.0\n1\n5e-1 leave 0\n",          // missing cores
            "1\n0 1.0\n1\n5e-1 slow 0 5e-1\n",      // missing duration
            "1\n0 1.0\n1\n5e-1 crash 0 7\n",        // trailing columns
            "1\n0 1.0\n1\n5e-1 crash 0\nextra\n",   // data after the events
            "1\n0 1.0\n1\n5e-1 crash zero\n",       // bad node
        ] {
            assert!(parse_tree_full(Cursor::new(bad)).is_err(), "{bad:?}");
        }
        // an explicit empty disturbance section is fine
        let (_, _, f) = parse_tree_full(Cursor::new("1\n0 1.0\n0\n")).unwrap();
        assert!(f.unwrap().is_empty());
    }

    #[test]
    fn rejects_truncated() {
        let text = "3\n0 1.0\n";
        assert!(parse_tree(Cursor::new(text)).is_err());
    }
}
