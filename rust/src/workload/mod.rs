//! Assembly-tree workload datasets (the §7 simulation corpus).
//!
//! The paper runs on 600+ assembly trees extracted from the University
//! of Florida Sparse Matrix Collection (2 000–1 000 000 nodes, depth
//! 12–75 000). The collection is not available offline; per the
//! substitution rule this module builds a surrogate corpus from two
//! sources (DESIGN.md §2):
//!
//! * **real analysis trees** — elimination/assembly trees of generated
//!   sparse problems (2D/3D grid Laplacians under nested dissection,
//!   random SPD under RCM) produced by [`crate::sparse`] — these carry
//!   the true multifrontal shape (separator-dominated top, bushy
//!   bottom, front-flop task weights);
//! * **parametric random trees** — spanning the collection's size and
//!   depth ranges, from bushy/flat to caterpillar-deep, with
//!   log-normally distributed task lengths.
//!
//! [`trace`] serializes trees to a dependency-free text format so
//! datasets are reproducible artifacts; the v2 extension carries the
//! per-task memory weights of [`crate::mem::MemWeights`]
//! ([`generator::synthetic_mem_weights`] produces the synthetic
//! family for random trees), and the v4 extension carries multi-job
//! arrival traces (tenant/arrival/priority/deadline per job) for the
//! online service, whose stochastic arrival processes
//! ([`generator::arrival_times`]) also live here.

pub mod generator;
pub mod trace;

pub use generator::{
    arrival_times, dataset, random_fault_trace, random_link_fault_trace, synthetic_mem_weights,
    ArrivalProcess, DatasetSpec, TreeClass,
};
pub use trace::{
    read_jobs, read_tree, read_tree_faults, read_tree_mem, write_jobs, write_tree,
    write_tree_faults, write_tree_mem, TraceJob,
};
