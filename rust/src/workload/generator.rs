//! Dataset generation: assembly trees from real analysis plus
//! parametric random trees calibrated to the paper's corpus.

use crate::model::TaskTree;
use crate::sparse::{gen, order, symbolic};
use crate::util::rng::Rng;

/// Structural classes of random trees, chosen to span the collection's
/// spectrum from flat/bushy (finite-element meshes with good
/// separators) to extremely deep (banded/chain-like problems — the
/// paper reports depths up to 75 000).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TreeClass {
    /// Random attachment to any earlier node: depth ~ log n, bushy.
    Uniform,
    /// Preferential attachment to recent nodes: moderate depth.
    Recent,
    /// Caterpillar-like: long trunk with small dangling subtrees.
    Deep,
    /// Balanced binary-ish.
    Binary,
}

/// Dataset specification.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    /// Number of random trees.
    pub random_trees: usize,
    /// Node-count range (log-uniform), paper: 2k–1M.
    pub min_nodes: usize,
    pub max_nodes: usize,
    /// Whether to prepend the analysis trees of generated sparse
    /// problems (adds ~a dozen "real" trees).
    pub include_analysis_trees: bool,
    /// RNG seed.
    pub seed: u64,
}

impl Default for DatasetSpec {
    fn default() -> Self {
        // Default sized so the full Figure-13/14 sweep stays in CI
        // budget; the benches scale `random_trees`/`max_nodes` up to
        // the paper's corpus dimensions via flags.
        DatasetSpec {
            random_trees: 600,
            min_nodes: 2_000,
            max_nodes: 50_000,
            include_analysis_trees: true,
            seed: 0xDA7A,
        }
    }
}

/// Generate one random tree of `n` nodes in the given class, with
/// log-normal task lengths (heavier tasks near the root, as in real
/// assembly trees where separator fronts dominate).
pub fn random_tree(class: TreeClass, n: usize, rng: &mut Rng) -> TaskTree {
    assert!(n >= 1);
    let mut parents = vec![0usize; n];
    // node 0 is the root; children attach to earlier nodes
    for i in 1..n {
        parents[i] = match class {
            TreeClass::Uniform => rng.below(i),
            TreeClass::Recent => {
                // attach near the frontier: parent in the last ~sqrt(i)
                let w = (i as f64).sqrt().ceil() as usize;
                i - 1 - rng.below(w.min(i))
            }
            TreeClass::Deep => {
                // long trunk: 85% attach to the previous node
                if rng.bool(0.85) {
                    i - 1
                } else {
                    rng.below(i)
                }
            }
            TreeClass::Binary => (i - 1) / 2,
        };
    }
    // depth-dependent lengths: nodes closer to the root get heavier
    // (multifrontal fronts grow toward the separators at the top)
    let mut depth = vec![0u32; n];
    for i in 1..n {
        depth[i] = depth[parents[i]] + 1;
    }
    let max_d = *depth.iter().max().unwrap() as f64;
    let lens: Vec<f64> = (0..n)
        .map(|i| {
            let rel = 1.0 - depth[i] as f64 / (max_d + 1.0); // 1 at root
            let scale = (3.0 * rel).exp(); // ~20x root-to-leaf ratio
            scale * rng.log_normal(0.0, 0.8)
        })
        .collect();
    TaskTree::from_parents(&parents, &lens).unwrap()
}

/// Root-dominated, shape-diverse family for the distributed mapping
/// study (§6, the `dist_sim` bench): a heavy root over `pairs`
/// chain-shaped branches (`Leq = work`) interleaved with `pairs`
/// bushy branches (`Leq ≪ work` for α < 1) of exactly equal work.
/// Balancing raw work (proportional mapping) cannot tell the two
/// shapes apart and pairs chains on a node; balancing power-lengths
/// (Algorithm 11 generalized) separates them — the family where the
/// speedup-aware mapping provably wins. `c` scales every task length.
pub fn root_shape_mix(pairs: usize, c: f64, chain_len: usize, leaves: usize) -> TaskTree {
    assert!(pairs >= 1 && chain_len >= 1 && leaves >= 1);
    // bushy leaves sized so both branch kinds carry chain_len · c work
    let leaf_len = chain_len as f64 * c / leaves as f64;
    let mut parents = vec![0usize];
    let mut lens = vec![chain_len as f64 * c]; // the dominating root
    for _ in 0..pairs {
        // chain branch: chain_len tasks of length c
        parents.push(0);
        lens.push(c);
        for _ in 1..chain_len {
            parents.push(parents.len() - 1);
            lens.push(c);
        }
        // bushy branch: `leaves` parallel leaves under a 0-length root
        let broot = parents.len();
        parents.push(0);
        lens.push(0.0);
        for _ in 0..leaves {
            parents.push(broot);
            lens.push(leaf_len);
        }
    }
    TaskTree::from_parents(&parents, &lens).unwrap()
}

/// Synthetic per-task memory weights for a random tree, calibrated to
/// dense-front scaling: a front doing `L` flops is roughly `n × n`
/// with `L ∝ n³`, so its storage scales as `L^{2/3}` (jittered
/// log-normally). The contribution block is a random trailing
/// sub-block (`cb ≤ front`); the root keeps none, matching the
/// multifrontal root front (`m = 0`). This is the synthetic
/// counterpart of [`crate::mem::MemWeights::from_symbolic`] for trees
/// that did not come from a real analysis.
pub fn synthetic_mem_weights(tree: &TaskTree, rng: &mut Rng) -> crate::mem::MemWeights {
    let n = tree.len();
    let mut front = Vec::with_capacity(n);
    let mut cb = Vec::with_capacity(n);
    for (i, node) in tree.nodes.iter().enumerate() {
        let f = node.len.max(1e-9).powf(2.0 / 3.0) * rng.log_normal(0.0, 0.3);
        front.push(f);
        cb.push(if i as u32 == tree.root {
            0.0
        } else {
            f * rng.range_f64(0.1, 0.8)
        });
    }
    crate::mem::MemWeights { front, cb }
}

/// Seedable random disturbance trace over `n_nodes` platform nodes
/// (DESIGN.md §13): `events` events uniform in `(0, horizon)`, mixing
/// crashes (at most `n_nodes − 1`, so the platform survives),
/// leave/join pairs of whole cores, and transient slowdowns. With
/// `n_nodes == 1` no crashes are generated. Determinism comes from
/// `rng` alone, so fault experiments are reproducible artifacts.
pub fn random_fault_trace(
    n_nodes: usize,
    horizon: f64,
    events: usize,
    rng: &mut Rng,
) -> crate::model::FaultTrace {
    use crate::model::{FaultEvent, FaultKind};
    let mut out = Vec::with_capacity(events);
    let mut crashes_left = n_nodes.saturating_sub(1);
    for _ in 0..events {
        let time = rng.range_f64(0.0, horizon).max(horizon * 1e-6);
        let node = rng.below(n_nodes);
        let kind = match rng.below(4) {
            0 if crashes_left > 0 => {
                crashes_left -= 1;
                FaultKind::Crash { node }
            }
            1 => FaultKind::Leave { node, cores: (1 + rng.below(2)) as f64 },
            2 => FaultKind::Join { node, cores: (1 + rng.below(2)) as f64 },
            _ => FaultKind::Slowdown {
                node,
                factor: rng.range_f64(0.2, 0.9),
                duration: rng.range_f64(0.05, 0.3) * horizon,
            },
        };
        out.push(FaultEvent { time, kind });
    }
    crate::model::FaultTrace::new(out)
}

/// Seedable random *link*-disturbance trace over `n_nodes ≥ 2`
/// platform nodes (DESIGN.md §15): `events` events uniform in
/// `(0, horizon)`, mixing bandwidth degradations and bounded link
/// severances over random node pairs. Kept separate from
/// [`random_fault_trace`] so the compute-fault streams (and the
/// benches seeded on them) are unchanged by the network layer.
pub fn random_link_fault_trace(
    n_nodes: usize,
    horizon: f64,
    events: usize,
    rng: &mut Rng,
) -> crate::model::FaultTrace {
    use crate::model::{FaultEvent, FaultKind};
    assert!(n_nodes >= 2, "link faults need at least two nodes, got {n_nodes}");
    let mut out = Vec::with_capacity(events);
    for _ in 0..events {
        let time = rng.range_f64(0.0, horizon).max(horizon * 1e-6);
        let a = rng.below(n_nodes);
        let b = (a + 1 + rng.below(n_nodes - 1)) % n_nodes;
        let duration = rng.range_f64(0.05, 0.3) * horizon;
        let kind = if rng.bool(0.5) {
            FaultKind::LinkDegrade { a, b, factor: rng.range_f64(0.05, 0.5), duration }
        } else {
            FaultKind::LinkDown { a, b, duration }
        };
        out.push(FaultEvent { time, kind });
    }
    crate::model::FaultTrace::new(out)
}

/// Stochastic job-arrival processes for the online service
/// (DESIGN.md §14). Every draw comes from the caller's [`Rng`] alone,
/// so arrival streams are reproducible artifacts; all three processes
/// share the same long-run mean rate, so load sweeps compare like
/// with like.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless Poisson process: exponential interarrivals with mean
    /// `1/rate`.
    Poisson {
        /// Mean arrivals per unit time.
        rate: f64,
    },
    /// On/off burst process: silent gaps with mean `burst/rate`
    /// separate bursts of mean size `burst` back-to-back arrivals, so
    /// the long-run rate stays `rate` while short-term demand spikes.
    Bursty {
        /// Long-run mean arrivals per unit time.
        rate: f64,
        /// Mean burst size (>= 1; 1 degenerates to Poisson-like gaps).
        burst: f64,
    },
    /// Heavy-tailed Pareto interarrivals with tail index `shape` > 1
    /// and mean `1/rate`: occasional very long quiet periods followed
    /// by dense clusters.
    HeavyTailed {
        /// Long-run mean arrivals per unit time.
        rate: f64,
        /// Pareto tail index (> 1 so the mean exists; smaller =
        /// heavier tail).
        shape: f64,
    },
}

/// Draw `n` nondecreasing arrival times from `process`. Panics on
/// non-finite or non-positive rates (the CLI validates before calling;
/// library users get the contract in debug and release alike).
pub fn arrival_times(process: ArrivalProcess, n: usize, rng: &mut Rng) -> Vec<f64> {
    let exp = |rng: &mut Rng, mean: f64| -> f64 {
        // inverse-CDF with u in [0, 1): -ln(1-u) is finite
        -(1.0 - rng.range_f64(0.0, 1.0)).ln() * mean
    };
    let check = |rate: f64| {
        assert!(rate.is_finite() && rate > 0.0, "arrival rate must be finite and > 0");
    };
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0;
    match process {
        ArrivalProcess::Poisson { rate } => {
            check(rate);
            for _ in 0..n {
                t += exp(rng, 1.0 / rate);
                out.push(t);
            }
        }
        ArrivalProcess::Bursty { rate, burst } => {
            check(rate);
            assert!(burst >= 1.0 && burst.is_finite(), "burst size must be finite and >= 1");
            while out.len() < n {
                // gap with mean burst/rate, then a burst of
                // uniform-sized back-to-back arrivals (mean `burst`)
                t += exp(rng, burst / rate);
                let k = 1 + rng.below((2.0 * burst).ceil() as usize - 1);
                for _ in 0..k.min(n - out.len()) {
                    out.push(t);
                }
            }
        }
        ArrivalProcess::HeavyTailed { rate, shape } => {
            check(rate);
            assert!(shape > 1.0 && shape.is_finite(), "pareto shape must be finite and > 1");
            // scale x_m chosen so the mean a·x_m/(a-1) equals 1/rate
            let xm = (shape - 1.0) / (shape * rate);
            for _ in 0..n {
                let u = 1.0 - rng.range_f64(0.0, 1.0); // u in (0, 1]
                t += xm * u.powf(-1.0 / shape);
                out.push(t);
            }
        }
    }
    out
}

/// Analysis trees of in-repo sparse problems (the "real" subset).
pub fn analysis_trees(rng: &mut Rng) -> Vec<(String, TaskTree)> {
    let mut out = Vec::new();
    for k in [24usize, 32, 48, 64] {
        let a = gen::grid_laplacian_2d(k);
        let perm = order::nested_dissection_2d(k);
        let at = symbolic::analyze(&a, &perm, 4).expect("analysis");
        out.push((format!("grid2d_{k}x{k}"), at.tree));
    }
    for k in [8usize, 10, 12] {
        let a = gen::grid_laplacian_3d(k);
        let perm = order::nested_dissection_3d(k);
        let at = symbolic::analyze(&a, &perm, 4).expect("analysis");
        out.push((format!("grid3d_{k}^3"), at.tree));
    }
    for n in [500usize, 1500] {
        let a = gen::random_spd(n, 4, rng);
        let perm = order::reverse_cuthill_mckee(&a);
        let at = symbolic::analyze(&a, &perm, 4).expect("analysis");
        out.push((format!("rand_spd_{n}"), at.tree));
    }
    out
}

/// Generate the full dataset: `(name, tree)` pairs.
pub fn dataset(spec: &DatasetSpec) -> Vec<(String, TaskTree)> {
    let mut rng = Rng::new(spec.seed);
    let mut out = Vec::new();
    if spec.include_analysis_trees {
        out.extend(analysis_trees(&mut rng));
    }
    let classes = [
        TreeClass::Uniform,
        TreeClass::Recent,
        TreeClass::Deep,
        TreeClass::Binary,
    ];
    for i in 0..spec.random_trees {
        let class = classes[i % classes.len()];
        let n = rng
            .log_uniform(spec.min_nodes as f64, spec.max_nodes as f64)
            .round() as usize;
        let mut tree_rng = rng.fork();
        let tree = random_tree(class, n.max(2), &mut tree_rng);
        out.push((format!("rand_{class:?}_{i}_n{n}"), tree));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_trees_are_valid_all_classes() {
        let mut rng = Rng::new(1);
        for class in [
            TreeClass::Uniform,
            TreeClass::Recent,
            TreeClass::Deep,
            TreeClass::Binary,
        ] {
            let t = random_tree(class, 500, &mut rng);
            t.validate().unwrap();
            assert_eq!(t.len(), 500);
        }
    }

    #[test]
    fn random_fault_traces_are_valid_sorted_and_deterministic() {
        for n_nodes in [1usize, 2, 4] {
            let mut rng = Rng::new(0xFA);
            let t = random_fault_trace(n_nodes, 100.0, 12, &mut rng);
            t.validate(n_nodes).unwrap();
            assert_eq!(t.len(), 12);
            for w in t.events.windows(2) {
                assert!(w[0].time <= w[1].time, "trace must be time-sorted");
            }
            assert!(t.crashes() < n_nodes.max(1), "platform must survive");
            let mut rng2 = Rng::new(0xFA);
            assert_eq!(t, random_fault_trace(n_nodes, 100.0, 12, &mut rng2));
        }
        let mut rng = Rng::new(0xFB);
        assert!(random_fault_trace(1, 50.0, 40, &mut rng).crashes() == 0);
    }

    #[test]
    fn random_link_fault_traces_are_valid_and_deterministic() {
        for n_nodes in [2usize, 3, 5] {
            let mut rng = Rng::new(0xFC);
            let t = random_link_fault_trace(n_nodes, 100.0, 10, &mut rng);
            t.validate(n_nodes).unwrap();
            assert_eq!(t.len(), 10);
            assert_eq!(t.link_events(), 10, "every event targets a link");
            for w in t.events.windows(2) {
                assert!(w[0].time <= w[1].time, "trace must be time-sorted");
            }
            let mut rng2 = Rng::new(0xFC);
            assert_eq!(t, random_link_fault_trace(n_nodes, 100.0, 10, &mut rng2));
        }
    }

    #[test]
    fn deep_class_is_deeper_than_uniform() {
        let mut rng = Rng::new(2);
        let n = 2000;
        let deep = random_tree(TreeClass::Deep, n, &mut rng);
        let uni = random_tree(TreeClass::Uniform, n, &mut rng);
        assert!(
            deep.height() > 3 * uni.height(),
            "deep {} vs uniform {}",
            deep.height(),
            uni.height()
        );
    }

    #[test]
    fn lengths_heavier_near_root() {
        let mut rng = Rng::new(3);
        let t = random_tree(TreeClass::Uniform, 3000, &mut rng);
        let depths = t.depths();
        let max_d = *depths.iter().max().unwrap();
        let shallow: Vec<f64> = (0..t.len())
            .filter(|&i| depths[i] <= max_d / 4)
            .map(|i| t.nodes[i].len)
            .collect();
        let deep: Vec<f64> = (0..t.len())
            .filter(|&i| depths[i] >= 3 * max_d / 4)
            .map(|i| t.nodes[i].len)
            .collect();
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        assert!(mean(&shallow) > 2.0 * mean(&deep));
    }

    #[test]
    fn root_shape_mix_has_equal_work_branches() {
        let t = root_shape_mix(3, 2.0, 4, 5);
        t.validate().unwrap();
        let w = t.subtree_work();
        let branches = &t.nodes[t.root as usize].children;
        assert_eq!(branches.len(), 6);
        for &b in branches {
            assert!((w[b as usize] - 8.0).abs() < 1e-12, "branch work {}", w[b as usize]);
        }
        // root carries one branch's worth of work itself
        assert_eq!(t.nodes[t.root as usize].len, 8.0);
    }

    #[test]
    fn synthetic_mem_weights_are_valid_and_scale_with_length() {
        let mut rng = Rng::new(0x3E3);
        let t = random_tree(TreeClass::Uniform, 800, &mut rng);
        let w = synthetic_mem_weights(&t, &mut rng);
        w.validate(&t).unwrap();
        assert_eq!(w.cb[t.root as usize], 0.0);
        // heavier tasks carry more memory on average (2/3-power law)
        let mut idx: Vec<usize> = (0..t.len()).collect();
        idx.sort_by(|&a, &b| t.nodes[a].len.total_cmp(&t.nodes[b].len));
        let q = t.len() / 4;
        let mean = |ix: &[usize]| ix.iter().map(|&i| w.front[i]).sum::<f64>() / ix.len() as f64;
        assert!(mean(&idx[t.len() - q..]) > 2.0 * mean(&idx[..q]));
    }

    #[test]
    fn arrival_processes_match_their_mean_rate() {
        // all three processes share the long-run rate, so load sweeps
        // over λ compare like with like (20% tolerance on 4000 draws;
        // heavy tails get 35%)
        let n = 4000;
        for (process, tol) in [
            (ArrivalProcess::Poisson { rate: 3.0 }, 0.2),
            (ArrivalProcess::Bursty { rate: 3.0, burst: 5.0 }, 0.2),
            (ArrivalProcess::HeavyTailed { rate: 3.0, shape: 2.5 }, 0.35),
        ] {
            let mut rng = Rng::new(0xA221);
            let times = arrival_times(process, n, &mut rng);
            assert_eq!(times.len(), n);
            assert!(times[0] >= 0.0);
            for w in times.windows(2) {
                assert!(w[1] >= w[0], "{process:?}: arrivals must be nondecreasing");
            }
            let rate = n as f64 / times[n - 1];
            assert!(
                (rate - 3.0).abs() <= 3.0 * tol,
                "{process:?}: empirical rate {rate:.3} vs 3.0"
            );
        }
    }

    #[test]
    fn arrival_streams_are_deterministic_and_bursty_clusters() {
        let p = ArrivalProcess::Bursty { rate: 2.0, burst: 6.0 };
        let a = arrival_times(p, 500, &mut Rng::new(7));
        let b = arrival_times(p, 500, &mut Rng::new(7));
        assert_eq!(a, b);
        // bursts produce ties (back-to-back arrivals) that a Poisson
        // stream essentially never does
        let ties = a.windows(2).filter(|w| w[0] == w[1]).count();
        assert!(ties > 100, "bursty stream had only {ties} tied arrivals");
        let pois = arrival_times(ArrivalProcess::Poisson { rate: 2.0 }, 500, &mut Rng::new(7));
        assert_eq!(pois.windows(2).filter(|w| w[0] == w[1]).count(), 0);
    }

    #[test]
    fn dataset_is_deterministic() {
        let spec = DatasetSpec {
            random_trees: 6,
            min_nodes: 100,
            max_nodes: 1000,
            include_analysis_trees: false,
            seed: 42,
        };
        let a = dataset(&spec);
        let b = dataset(&spec);
        assert_eq!(a.len(), 6);
        for ((na, ta), (nb, tb)) in a.iter().zip(&b) {
            assert_eq!(na, nb);
            assert_eq!(ta.len(), tb.len());
            assert_eq!(ta.total_work(), tb.total_work());
        }
    }

    #[test]
    fn dataset_includes_analysis_trees() {
        let spec = DatasetSpec {
            random_trees: 0,
            min_nodes: 100,
            max_nodes: 200,
            include_analysis_trees: true,
            seed: 7,
        };
        let d = dataset(&spec);
        assert!(d.len() >= 8);
        assert!(d.iter().any(|(n, _)| n.starts_with("grid2d")));
        assert!(d.iter().any(|(n, _)| n.starts_with("grid3d")));
        assert!(d.iter().any(|(n, _)| n.starts_with("rand_spd")));
        for (_, t) in &d {
            t.validate().unwrap();
        }
    }

    #[test]
    fn sizes_span_requested_range() {
        let spec = DatasetSpec {
            random_trees: 40,
            min_nodes: 1_000,
            max_nodes: 20_000,
            include_analysis_trees: false,
            seed: 9,
        };
        let d = dataset(&spec);
        let sizes: Vec<usize> = d.iter().map(|(_, t)| t.len()).collect();
        assert!(sizes.iter().any(|&s| s < 3_000));
        assert!(sizes.iter().any(|&s| s > 10_000));
    }
}
