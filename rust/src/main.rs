fn main() -> anyhow::Result<()> {
    malltree::cli::run(std::env::args().skip(1).collect())
}
