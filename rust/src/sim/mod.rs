//! Simulators.
//!
//! * [`des`] — a discrete-event simulator that executes any
//!   share-allocation *policy* over a malleable task tree under the
//!   `p^α` model; it independently cross-checks the analytic makespans
//!   of [`crate::sched`] (the two are implemented from different
//!   first principles, so agreement is a strong correctness signal);
//! * [`kerneldag`] — the §3-reproduction substrate: tiled
//!   Cholesky/QR/frontal kernel DAGs list-scheduled on `p` cores with a
//!   shared memory-bandwidth roofline, producing the `T(p)` curves and
//!   α fits of Figures 2–6 / Tables 1–2 (DESIGN.md §2 explains why this
//!   simulator substitutes for the paper's 40-core machine).
//!
//! The DES also has a distributed mode
//! ([`des::simulate_distributed`], paper §6): per-node static-share
//! schedules over a task→node mapping, with cross-node dependency
//! stalls (DESIGN.md §11).

pub mod des;
pub mod kerneldag;

pub use des::{simulate, simulate_distributed, DesResult, DistDesResult, Policy};
pub use kerneldag::{simulate_dag, timing_curve, KernelDag, MachineModel};
