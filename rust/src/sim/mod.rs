//! Simulators.
//!
//! * [`des`] — a discrete-event simulator that executes any
//!   share-allocation *policy* over a malleable task tree under the
//!   `p^α` model; it independently cross-checks the analytic makespans
//!   of [`crate::sched`] (the two are implemented from different
//!   first principles, so agreement is a strong correctness signal);
//! * [`kerneldag`] — the §3-reproduction substrate: tiled
//!   Cholesky/QR/frontal kernel DAGs list-scheduled on `p` cores with a
//!   shared memory-bandwidth roofline, producing the `T(p)` curves and
//!   α fits of Figures 2–6 / Tables 1–2 (DESIGN.md §2 explains why this
//!   simulator substitutes for the paper's 40-core machine).
//!
//! The DES also has a distributed mode
//! ([`des::simulate_distributed`], paper §6): per-node static-share
//! schedules over a task→node mapping, with cross-node dependency
//! stalls (DESIGN.md §11), a **memory replay** mode
//! ([`memreplay`], DESIGN.md §12) that tracks live words over time for
//! any materialized schedule — shared or distributed — reporting peak,
//! timeline and cap-induced stalls against [`crate::mem::MemWeights`],
//! and a **fault replay** mode ([`faults`], DESIGN.md §13) that
//! disturbs the platform with a [`crate::model::FaultTrace`] (crashes,
//! elastic leave/join, transient slowdowns), re-solving shares at
//! every event and recovering crashes by subtree re-mapping with a
//! restart-from-scratch fallback, and an **online replay**
//! ([`online`], DESIGN.md §14) that drives the multi-tenant
//! [`crate::online::OnlineService`] over a job-arrival stream and
//! reports throughput, sojourn quantiles and SLO attainment.
//!
//! All of these engines share the timestamped [`event::EventHeap`]
//! (f64 time under `total_cmp`, FIFO on ties), as does the priced
//! network replay in [`crate::net`].

pub mod des;
pub mod event;
pub mod faults;
pub mod kerneldag;
pub mod memreplay;
pub mod online;

pub use des::{
    simulate, simulate_distributed, simulate_distributed_traced, simulate_traced, DesResult,
    DistDesResult, Policy,
};
pub use faults::{replay_faults, replay_faults_distributed, trace_replay, FaultReplay, RecoveryPolicy};
pub use kerneldag::{simulate_dag, timing_curve, KernelDag, MachineModel};
pub use memreplay::{replay_memory, replay_memory_spans, spans_from_completions, MemReplay};
pub use online::{simulate_online, trace_online, OnlineReport};
