//! Discrete-event simulation of malleable-task policies.
//!
//! The simulator advances from event to event (task completions and
//! profile breakpoints). Between events every running task `i` holds a
//! constant share `s_i` and performs work at rate `s_i^α`. A *policy*
//! decides the shares of the ready tasks at every event. Because this
//! engine integrates work numerically and independently of the
//! closed-form scheduler math, `DES(PM policy) == PmSolution.makespan`
//! is a powerful cross-check (and similarly for the baselines).

use crate::model::{Platform, TaskTree};
use crate::sched::profile::Profile;
use crate::sched::Schedule;

/// Share-allocation policies over the ready set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Prasanna–Musicus constant ratios (recomputed exactly as the
    /// closed form prescribes, then replayed dynamically).
    Pm,
    /// Pothen–Sun proportional mapping: share of a ready task = its
    /// frozen subtree-proportional allocation (α-unaware).
    Proportional,
    /// Everything sequential, full platform per task.
    Divisible,
    /// Equal split of the platform among ready tasks (a naive dynamic
    /// baseline, not in the paper — used by ablation benches).
    EqualSplit,
}

/// Result of a simulation run.
#[derive(Debug, Clone)]
pub struct DesResult {
    pub makespan: f64,
    /// Completion time per task.
    pub completion: Vec<f64>,
    /// Number of DES events processed.
    pub events: usize,
}

/// Speedup used by the DES: the realistic kink (`p` below one
/// processor) so that α-unaware policies are charged fairly, exactly
/// as §7 evaluates them. PM allocations stay ≥ 1 processor whenever
/// the tree was `Agreg`-transformed, in which case this matches `p^α`.
pub(crate) fn speedup(share: f64, alpha: f64) -> f64 {
    if share >= 1.0 {
        share.powf(alpha)
    } else {
        share
    }
}

/// Run `policy` on `tree` under a constant profile of `p` processors.
///
/// §Perf: the original implementation advanced every running task's
/// remaining work at every event — O(ready) per event, O(n²) on wide
/// trees (measured 0.9 kevents/s on a 100k-task tree). The engine now
/// picks an O(n log n) event structure per policy class:
///
/// * static-share policies (PM, Proportional): a task's rate is fixed
///   once it becomes ready, so completions go into a time-keyed heap —
///   no global work advance;
/// * `EqualSplit`: all ready tasks share one rate, so completion
///   *order* is threshold order in accumulated-speed space
///   `S(t) = ∫ rate dt`; tasks carry an absolute threshold
///   `S(start) + len` in a heap and the clock integrates `S` only at
///   events;
/// * `Divisible`: sequential by construction.
///
/// Measured after: >10 Mevents/s (EXPERIMENTS.md §Perf).
pub fn simulate(tree: &TaskTree, alpha: f64, p: f64, policy: Policy) -> DesResult {
    match policy {
        Policy::Pm | Policy::Proportional => simulate_static(tree, alpha, p, policy),
        Policy::EqualSplit => simulate_equal_split(tree, alpha, p),
        Policy::Divisible => simulate_divisible(tree, alpha, p),
    }
}

/// Map PM leaf ratios (indexed by SP node) back to task ids.
fn pm_leaf_ratios(
    g: &crate::model::SpGraph,
    sol: &crate::sched::pm::PmSolution,
    n: usize,
) -> Vec<f64> {
    let mut r = vec![0f64; n];
    crate::sched::pm::scatter_leaf_ratios(g, &sol.ratio, &mut r);
    r
}

/// [`simulate`] with a reusable [`crate::sched::SchedWorkspace`]: the
/// PM policy's closed-form solve runs through the workspace buffers,
/// and the per-task ratio vector lives in the workspace too
/// (`pm_task_ratios`), so sweeping many trees/α values (the batch and
/// bench paths) performs no per-simulation allocation in the policy
/// setup. Other policies delegate to [`simulate`] unchanged.
pub fn simulate_with_workspace(
    tree: &TaskTree,
    alpha: f64,
    p: f64,
    policy: Policy,
    ws: &mut crate::sched::SchedWorkspace,
) -> DesResult {
    match policy {
        Policy::Pm => {
            let g = crate::model::SpGraph::from_tree(tree);
            let r = ws.pm_task_ratios(&g, alpha, tree.len());
            simulate_with_ratios(tree, alpha, p, r)
        }
        _ => simulate(tree, alpha, p, policy),
    }
}

/// Static-share policies: every task runs at a fixed speedup from the
/// moment it becomes ready; completions pop from a time-keyed heap
/// (the shared [`super::event::EventHeap`]).
fn simulate_static(tree: &TaskTree, alpha: f64, p: f64, policy: Policy) -> DesResult {
    use super::event::EventHeap;
    let n = tree.len();
    let ratio = static_ratios(tree, alpha, p, policy);
    let mut unfinished: Vec<usize> = tree.nodes.iter().map(|t| t.children.len()).collect();
    let mut completion = vec![0f64; n];
    let mut start_max = vec![0f64; n]; // latest child completion per node
    let mut heap: EventHeap<u32> = EventHeap::with_capacity(n);
    let dur = |v: u32| -> f64 {
        let len = tree.nodes[v as usize].len;
        if len <= 0.0 {
            0.0
        } else {
            len / speedup(ratio[v as usize] * p, alpha)
        }
    };
    for v in 0..n as u32 {
        if unfinished[v as usize] == 0 {
            heap.push(dur(v), v);
        }
    }
    let mut events = 0usize;
    let mut makespan = 0.0f64;
    while let Some((t, v)) = heap.pop() {
        events += 1;
        completion[v as usize] = t;
        makespan = makespan.max(t);
        if let Some(parent) = tree.nodes[v as usize].parent {
            let pi = parent as usize;
            unfinished[pi] -= 1;
            start_max[pi] = start_max[pi].max(t);
            if unfinished[pi] == 0 {
                heap.push(start_max[pi] + dur(parent), parent);
            }
        }
    }
    DesResult { makespan, completion, events }
}

/// Static-share simulation with caller-provided per-task ratios
/// (used by the integer-share ablation: PM ratios rounded to whole
/// cores). The caller is responsible for feasibility.
pub fn simulate_with_ratios(tree: &TaskTree, alpha: f64, p: f64, ratios: &[f64]) -> DesResult {
    use super::event::EventHeap;
    let n = tree.len();
    assert_eq!(ratios.len(), n);
    let mut unfinished: Vec<usize> = tree.nodes.iter().map(|t| t.children.len()).collect();
    let mut completion = vec![0f64; n];
    let mut start_max = vec![0f64; n];
    let mut heap: EventHeap<u32> = EventHeap::with_capacity(n);
    let dur = |v: u32| -> f64 {
        let len = tree.nodes[v as usize].len;
        if len <= 0.0 {
            0.0
        } else {
            len / speedup(ratios[v as usize] * p, alpha)
        }
    };
    for v in 0..n as u32 {
        if unfinished[v as usize] == 0 {
            heap.push(dur(v), v);
        }
    }
    let mut events = 0usize;
    let mut makespan = 0.0f64;
    while let Some((t, v)) = heap.pop() {
        events += 1;
        completion[v as usize] = t;
        makespan = makespan.max(t);
        if let Some(parent) = tree.nodes[v as usize].parent {
            let pi = parent as usize;
            unfinished[pi] -= 1;
            start_max[pi] = start_max[pi].max(t);
            if unfinished[pi] == 0 {
                heap.push(start_max[pi] + dur(parent), parent);
            }
        }
    }
    DesResult { makespan, completion, events }
}

/// Result of a distributed simulation run
/// ([`simulate_distributed`]).
#[derive(Debug, Clone)]
pub struct DistDesResult {
    /// Global makespan (last completion over all nodes).
    pub makespan: f64,
    /// Completion time per task.
    pub completion: Vec<f64>,
    /// Number of DES events processed.
    pub events: usize,
    /// Completion time of the last task on each node (0 for nodes that
    /// received no task).
    pub node_finish: Vec<f64>,
    /// Tree edges whose endpoints are mapped to different nodes.
    pub cross_edges: usize,
    /// Total extra waiting caused by remote children: for every task,
    /// `max(0, latest remote-child completion − latest same-node-child
    /// completion)`, summed. Zero when the mapping cuts no edge on a
    /// critical wait.
    pub cross_stall: f64,
}

/// Distributed DES (paper §6): replay per-node static-share schedules
/// with cross-node dependency stalls.
///
/// Each node `k` owns the tasks with `node_of[t] == k`; its allocation
/// is computed over the *induced* node-local sub-forest (tree edges
/// with both endpoints on `k`): PM constant ratios for [`Policy::Pm`],
/// Pothen–Sun proportional shares for [`Policy::Proportional`] (the
/// other policies are not static-share and are rejected). A task runs
/// at `speedup(ratio · p_k)` from the moment every child — local *or
/// remote* — has completed: a parent whose children were mapped
/// elsewhere stalls until the slowest remote subtree finishes, which
/// is exactly the phase structure of Algorithm 11 when the mapping
/// came from [`crate::dist::mapping`].
///
/// With one node this degenerates bit-for-bit to the shared-memory
/// static engine ([`simulate`] under the same policy) — the whole-tree
/// path is the 1-node special case.
pub fn simulate_distributed(
    tree: &TaskTree,
    alpha: f64,
    platform: &Platform,
    node_of: &[usize],
    policy: Policy,
) -> DistDesResult {
    let mut ws = crate::sched::SchedWorkspace::new();
    simulate_distributed_with_workspace(tree, alpha, platform, node_of, policy, &mut ws)
}

/// [`simulate_distributed`] with a caller-owned workspace so mapping
/// sweeps (the `dist_sim` bench, the `distribute` pipeline) reuse the
/// solver buffers across nodes and runs.
pub fn simulate_distributed_with_workspace(
    tree: &TaskTree,
    alpha: f64,
    platform: &Platform,
    node_of: &[usize],
    policy: Policy,
    ws: &mut crate::sched::SchedWorkspace,
) -> DistDesResult {
    use super::event::EventHeap;
    let n = tree.len();
    assert_eq!(node_of.len(), n, "node_of must cover every task");
    let n_nodes = platform.num_nodes();
    for &k in node_of {
        assert!(k < n_nodes, "task mapped to node {k}, platform has {n_nodes} nodes");
    }
    assert!(
        matches!(policy, Policy::Pm | Policy::Proportional),
        "distributed DES replays static-share policies (Pm, Proportional), got {policy:?}"
    );

    let share = distributed_shares(tree, alpha, platform, node_of, policy, ws);

    // Event loop: identical structure to the shared static engine, but
    // with per-task shares and per-parent local/remote wait tracking.
    let mut unfinished: Vec<usize> = tree.nodes.iter().map(|t| t.children.len()).collect();
    let mut completion = vec![0f64; n];
    let mut ready_all = vec![0f64; n]; // latest child completion
    let mut ready_local = vec![0f64; n]; // latest same-node child completion
    let mut node_finish = vec![0f64; n_nodes];
    let mut cross_edges = 0usize;
    for (t, node) in tree.nodes.iter().enumerate() {
        if let Some(p) = node.parent {
            if node_of[t] != node_of[p as usize] {
                cross_edges += 1;
            }
        }
    }
    let dur = |v: u32| -> f64 {
        let len = tree.nodes[v as usize].len;
        if len <= 0.0 {
            0.0
        } else {
            len / speedup(share[v as usize], alpha)
        }
    };
    let mut heap: EventHeap<u32> = EventHeap::with_capacity(n);
    for v in 0..n as u32 {
        if unfinished[v as usize] == 0 {
            heap.push(dur(v), v);
        }
    }
    let mut events = 0usize;
    let mut makespan = 0.0f64;
    let mut cross_stall = 0.0f64;
    while let Some((t, v)) = heap.pop() {
        events += 1;
        let vi = v as usize;
        completion[vi] = t;
        makespan = makespan.max(t);
        node_finish[node_of[vi]] = node_finish[node_of[vi]].max(t);
        if let Some(parent) = tree.nodes[vi].parent {
            let pi = parent as usize;
            unfinished[pi] -= 1;
            ready_all[pi] = ready_all[pi].max(t);
            if node_of[pi] == node_of[vi] {
                ready_local[pi] = ready_local[pi].max(t);
            }
            if unfinished[pi] == 0 {
                cross_stall += (ready_all[pi] - ready_local[pi]).max(0.0);
                heap.push(ready_all[pi] + dur(parent), parent);
            }
        }
    }
    DistDesResult {
        makespan,
        completion,
        events,
        node_finish,
        cross_edges,
        cross_stall,
    }
}

/// Per-task absolute share (processors on the owning node) of the
/// distributed replay — each node's allocation computed over its
/// induced sub-forest. Shared between the engine and the span
/// derivation so traced teams are the exact simulated shares.
fn distributed_shares(
    tree: &TaskTree,
    alpha: f64,
    platform: &Platform,
    node_of: &[usize],
    policy: Policy,
    ws: &mut crate::sched::SchedWorkspace,
) -> Vec<f64> {
    let n = tree.len();
    let n_nodes = platform.num_nodes();
    let mut share = vec![0f64; n];
    let mut member = vec![false; n];
    for k in 0..n_nodes {
        for (t, m) in member.iter_mut().enumerate() {
            *m = node_of[t] == k;
        }
        let p_k = platform.node_cores(k);
        match policy {
            Policy::Pm => {
                if let Some(r) = ws.induced_task_ratios(tree, &member, alpha, n) {
                    for t in 0..n {
                        if member[t] {
                            share[t] = r[t] * p_k;
                        }
                    }
                }
            }
            Policy::Proportional => {
                if let Some(g) = crate::model::SpGraph::from_induced(tree, &member) {
                    let shares = crate::sched::proportional::proportional_shares(&g, p_k);
                    for &v in g.topo() {
                        if let crate::model::SpNode::Leaf { task: Some(t), .. } =
                            g.nodes[v as usize]
                        {
                            // ratio first, share second — the exact float
                            // path of the shared engine, so the 1-node
                            // case stays bit-identical to `simulate`
                            let ratio = shares[v as usize] / p_k;
                            share[t as usize] = ratio * p_k;
                        }
                    }
                }
            }
            _ => unreachable!(),
        }
    }
    share
}

/// [`simulate`] with span emission: the same run plus a model-time
/// [`crate::obs::TraceLog`] derived *exactly* from the completion
/// times ([`crate::obs::from_completions`] — static-share engines push
/// `completion = ready + duration`, so no event-loop instrumentation
/// is needed). Static policies carry their share as the span team;
/// Divisible runs sequentially on the full platform (explicit
/// durations, since ready time ≠ start time there); EqualSplit's
/// varying share is recorded as team 0 (unknown) with work-conserving
/// `[ready, completion]` windows.
pub fn simulate_traced(
    tree: &TaskTree,
    alpha: f64,
    p: f64,
    policy: Policy,
) -> (DesResult, crate::obs::TraceLog) {
    let res = simulate(tree, alpha, p, policy);
    let log = match policy {
        Policy::Pm | Policy::Proportional => {
            let teams: Vec<f64> =
                static_ratios(tree, alpha, p, policy).iter().map(|r| r * p).collect();
            crate::obs::from_completions("sim-des", tree, &res.completion, Some(&teams), None, None)
        }
        Policy::Divisible => {
            let rate = speedup(p, alpha);
            let durations: Vec<f64> = tree
                .nodes
                .iter()
                .map(|t| if t.len <= 0.0 { 0.0 } else { t.len / rate })
                .collect();
            let teams = vec![p; tree.len()];
            crate::obs::from_completions(
                "sim-des",
                tree,
                &res.completion,
                Some(&teams),
                Some(&durations),
                None,
            )
        }
        Policy::EqualSplit => {
            crate::obs::from_completions("sim-des", tree, &res.completion, None, None, None)
        }
    };
    (res, log)
}

/// [`simulate_distributed`] with span emission: one Factor span per
/// task on its owning node's track (team = the exact simulated share),
/// plus a Stall span per parent whose remote children finish after its
/// local ones — the Stall durations sum to the engine's `cross_stall`
/// (tested).
pub fn simulate_distributed_traced(
    tree: &TaskTree,
    alpha: f64,
    platform: &Platform,
    node_of: &[usize],
    policy: Policy,
) -> (DistDesResult, crate::obs::TraceLog) {
    let mut ws = crate::sched::SchedWorkspace::new();
    let res = simulate_distributed_with_workspace(tree, alpha, platform, node_of, policy, &mut ws);
    let teams = distributed_shares(tree, alpha, platform, node_of, policy, &mut ws);
    let log = crate::obs::from_completions(
        "sim-dist",
        tree,
        &res.completion,
        Some(&teams),
        None,
        Some(node_of),
    );
    (res, log)
}

fn static_ratios(tree: &TaskTree, alpha: f64, p: f64, policy: Policy) -> Vec<f64> {
    let g = crate::model::SpGraph::from_tree(tree);
    let n = tree.len();
    match policy {
        Policy::Pm => {
            let sol = crate::sched::pm::PmSolution::solve(&g, alpha);
            pm_leaf_ratios(&g, &sol, n)
        }
        Policy::Proportional => {
            let shares = crate::sched::proportional::proportional_shares(&g, p);
            let mut r = vec![0f64; n];
            for &v in g.topo() {
                if let crate::model::SpNode::Leaf { task: Some(t), .. } = g.nodes[v as usize] {
                    r[t as usize] = shares[v as usize] / p;
                }
            }
            r
        }
        _ => unreachable!(),
    }
}

/// Divisible: tasks run one at a time (topological order) on all `p`.
fn simulate_divisible(tree: &TaskTree, alpha: f64, p: f64) -> DesResult {
    let n = tree.len();
    let rate = speedup(p, alpha);
    let mut t = 0.0;
    let mut completion = vec![0f64; n];
    for &v in &tree.topo_up() {
        t += tree.nodes[v as usize].len / rate;
        completion[v as usize] = t;
    }
    DesResult { makespan: t, completion, events: n }
}

/// EqualSplit: the shared rate changes at every event, but the ready
/// tasks always progress in lockstep, so completion order equals
/// threshold order in accumulated-speed space.
fn simulate_equal_split(tree: &TaskTree, alpha: f64, p: f64) -> DesResult {
    use super::event::EventHeap;
    let n = tree.len();
    let mut unfinished: Vec<usize> = tree.nodes.iter().map(|t| t.children.len()).collect();
    let mut completion = vec![0f64; n];
    let mut start_max = vec![0f64; n]; // latest child completion per node
    // heap keyed by absolute threshold S_done(start) + len
    let mut heap: EventHeap<u32> = EventHeap::with_capacity(n);
    let mut s_done = 0.0f64; // accumulated per-task progress
    let mut t = 0.0f64;
    let mut active = 0usize;
    for v in 0..n as u32 {
        if unfinished[v as usize] == 0 {
            heap.push(tree.nodes[v as usize].len, v);
            active += 1;
        }
    }
    let mut events = 0usize;
    while let Some((threshold, v)) = heap.pop() {
        events += 1;
        // advance wall clock to this completion: remaining per-task
        // progress needed...
        let need = threshold - s_done;
        if need > 0.0 {
            let rate = speedup(p / active as f64, alpha);
            t += need / rate;
            s_done = threshold;
        }
        active -= 1;
        completion[v as usize] = t;
        if let Some(parent) = tree.nodes[v as usize].parent {
            let pi = parent as usize;
            unfinished[pi] -= 1;
            start_max[pi] = start_max[pi].max(t);
            if unfinished[pi] == 0 {
                heap.push(s_done + tree.nodes[pi].len, parent);
                active += 1;
            }
        }
    }
    DesResult { makespan: t, completion, events }
}

/// Reference engine: the straightforward work-integrating event loop
/// (kept as the oracle the optimized engines are tested against — see
/// `prop_fast_engines_match_reference`).
pub fn simulate_reference(tree: &TaskTree, alpha: f64, p: f64, policy: Policy) -> DesResult {
    let n = tree.len();
    // Static allocations for the share-per-task policies.
    let static_ratio: Option<Vec<f64>> = match policy {
        Policy::Pm => {
            let g = crate::model::SpGraph::from_tree(tree);
            let sol = crate::sched::pm::PmSolution::solve(&g, alpha);
            // map leaf ratios back to task ids
            let mut r = vec![0f64; n];
            for &v in &g.topo_down() {
                if let crate::model::SpNode::Leaf { task, .. } = g.nodes[v as usize] {
                    if let Some(t) = task {
                        r[t as usize] = sol.ratio[v as usize];
                    }
                }
            }
            Some(r)
        }
        Policy::Proportional => {
            let g = crate::model::SpGraph::from_tree(tree);
            let shares = crate::sched::proportional::proportional_shares(&g, p);
            let mut r = vec![0f64; n];
            for &v in &g.topo_down() {
                if let crate::model::SpNode::Leaf { task, .. } = g.nodes[v as usize] {
                    if let Some(t) = task {
                        r[t as usize] = shares[v as usize] / p;
                    }
                }
            }
            Some(r)
        }
        _ => None,
    };

    let mut remaining: Vec<f64> = tree.nodes.iter().map(|t| t.len).collect();
    let mut unfinished_children: Vec<usize> =
        tree.nodes.iter().map(|t| t.children.len()).collect();
    let mut done = vec![false; n];
    let mut completion = vec![0f64; n];
    let mut ready: Vec<u32> = (0..n as u32)
        .filter(|&v| unfinished_children[v as usize] == 0)
        .collect();
    // Divisible runs tasks one at a time in topological order.
    let topo_pos: Vec<usize> = {
        let mut pos = vec![0usize; n];
        for (i, &v) in tree.topo_up().iter().enumerate() {
            pos[v as usize] = i;
        }
        pos
    };

    let mut t = 0.0f64;
    let mut events = 0usize;
    let mut completed = 0usize;
    while completed < n {
        events += 1;
        assert!(!ready.is_empty(), "deadlock: no ready tasks");
        // decide shares
        let shares: Vec<(u32, f64)> = match policy {
            Policy::Pm | Policy::Proportional => {
                let r = static_ratio.as_ref().unwrap();
                ready.iter().map(|&v| (v, r[v as usize] * p)).collect()
            }
            Policy::Divisible => {
                let &first = ready
                    .iter()
                    .min_by_key(|&&v| topo_pos[v as usize])
                    .unwrap();
                vec![(first, p)]
            }
            Policy::EqualSplit => {
                let s = p / ready.len() as f64;
                ready.iter().map(|&v| (v, s)).collect()
            }
        };
        // zero-length ready tasks complete instantly
        let mut instant: Vec<u32> = ready
            .iter()
            .copied()
            .filter(|&v| remaining[v as usize] <= 0.0)
            .collect();
        let dt = if instant.is_empty() {
            // time to first completion among allocated tasks
            shares
                .iter()
                .filter(|&&(v, s)| s > 0.0 && remaining[v as usize] > 0.0)
                .map(|&(v, s)| remaining[v as usize] / speedup(s, alpha))
                .fold(f64::INFINITY, f64::min)
        } else {
            0.0
        };
        assert!(dt.is_finite(), "no task can progress (all shares zero)");
        // advance work
        if dt > 0.0 {
            for &(v, s) in &shares {
                if s > 0.0 {
                    remaining[v as usize] -= dt * speedup(s, alpha);
                }
            }
            t += dt;
        }
        // collect completions (numeric slack for simultaneous finishes)
        for &(v, s) in &shares {
            if s > 0.0 && !done[v as usize] && remaining[v as usize] <= 1e-9 * tree.nodes[v as usize].len.max(1.0) {
                instant.push(v);
            }
        }
        instant.sort_unstable();
        instant.dedup();
        for v in instant {
            let vi = v as usize;
            if done[vi] {
                continue;
            }
            done[vi] = true;
            remaining[vi] = 0.0;
            completion[vi] = t;
            completed += 1;
            ready.retain(|&x| x != v);
            if let Some(parent) = tree.nodes[vi].parent {
                let pi = parent as usize;
                unfinished_children[pi] -= 1;
                if unfinished_children[pi] == 0 {
                    ready.push(parent);
                }
            }
        }
    }
    DesResult { makespan: t, completion, events }
}

/// Replay a materialized [`Schedule`] and report the work each task
/// accumulated (independent check of schedule validity).
pub fn replay_schedule(
    tree: &TaskTree,
    schedule: &Schedule,
    alpha: f64,
    profile: &Profile,
) -> Vec<f64> {
    let mut work = vec![0f64; tree.len()];
    for span in &schedule.spans {
        work[span.task as usize] += Schedule::span_work(span, alpha, profile);
    }
    work
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SpGraph;
    use crate::sched::pm::PmSolution;
    use crate::sched::proportional::proportional_makespan;
    use crate::sched::divisible::divisible_makespan_tree;
    use crate::util::approx_eq;
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;

    fn tree5() -> TaskTree {
        TaskTree::from_parents(&[0, 0, 0, 1, 1], &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap()
    }

    #[test]
    fn des_pm_matches_closed_form() {
        let t = tree5();
        for &a in &[0.5, 0.7, 0.9, 1.0] {
            let p = 10.0;
            let des = simulate(&t, a, p, Policy::Pm);
            let pm = PmSolution::solve(&SpGraph::from_tree(&t), a).makespan_const(p);
            assert!(
                approx_eq(des.makespan, pm, 1e-6),
                "alpha={a}: des={} pm={pm}",
                des.makespan
            );
        }
    }

    #[test]
    fn des_pm_with_workspace_matches_plain_and_closed_form() {
        // the workspace is deliberately reused across trees and α
        // values: stale buffer contents must never leak into a run
        let mut ws = crate::sched::SchedWorkspace::new();
        let trees = [
            tree5(),
            TaskTree::from_parents(&[0, 0, 1, 1, 2, 2, 3], &[9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0])
                .unwrap(),
        ];
        for t in &trees {
            for &a in &[0.6, 0.9, 1.0] {
                let p = 10.0;
                let plain = simulate(t, a, p, Policy::Pm);
                let wsd = simulate_with_workspace(t, a, p, Policy::Pm, &mut ws);
                assert_eq!(plain.makespan.to_bits(), wsd.makespan.to_bits());
                assert_eq!(plain.events, wsd.events);
                let pm = PmSolution::solve(&SpGraph::from_tree(t), a).makespan_const(p);
                assert!(approx_eq(wsd.makespan, pm, 1e-6));
            }
        }
    }

    #[test]
    fn des_proportional_matches_closed_form() {
        let t = tree5();
        let (a, p) = (0.8, 12.0);
        let des = simulate(&t, a, p, Policy::Proportional);
        let cf = proportional_makespan(&SpGraph::from_tree(&t), a, p);
        assert!(approx_eq(des.makespan, cf, 1e-6), "des={} cf={cf}", des.makespan);
    }

    #[test]
    fn des_divisible_matches_closed_form() {
        let t = tree5();
        let (a, p) = (0.6, 7.0);
        let des = simulate(&t, a, p, Policy::Divisible);
        let cf = divisible_makespan_tree(&t, a, p);
        assert!(approx_eq(des.makespan, cf, 1e-9));
    }

    #[test]
    fn pm_dominates_everything_randomized() {
        check(
            Config { cases: 40, seed: 3 },
            "PM optimality vs other policies (DES)",
            |rng: &mut Rng| {
                let n = rng.range(2, 30);
                let parents: Vec<usize> =
                    (0..n).map(|i| if i == 0 { 0 } else { rng.below(i) }).collect();
                // lengths >= p so that PM shares stay >= 1 processor and
                // the realistic kink never activates for PM
                let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(50.0, 500.0)).collect();
                let alpha = rng.range_f64(0.5, 1.0);
                (TaskTree::from_parents(&parents, &lens).unwrap(), alpha)
            },
            |(tree, alpha)| {
                // Soundness of the comparison: the pure-model PM
                // makespan is optimal among *all* pure-model schedules,
                // and the kinked (realistic) speedup only slows the
                // baselines down, so PM-pure <= baseline-kinked always.
                let p = 4.0;
                let g = SpGraph::from_tree(tree);
                let pm = PmSolution::solve(&g, *alpha).makespan_const(p);
                for pol in [Policy::Proportional, Policy::Divisible, Policy::EqualSplit] {
                    let other = simulate(tree, *alpha, p, pol).makespan;
                    if pm > other * (1.0 + 1e-6) {
                        return Err(format!("PM {pm} beat by {pol:?} {other}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fast_engines_match_reference() {
        // the optimized per-policy engines must agree with the
        // straightforward work-integrating loop on random trees
        check(
            Config { cases: 40, seed: 21 },
            "fast DES == reference DES",
            |rng: &mut Rng| {
                let n = rng.range(2, 60);
                let parents: Vec<usize> =
                    (0..n).map(|i| if i == 0 { 0 } else { rng.below(i) }).collect();
                let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(0.1, 100.0)).collect();
                let alpha = rng.range_f64(0.4, 1.0);
                let p = rng.range_f64(1.0, 64.0);
                (TaskTree::from_parents(&parents, &lens).unwrap(), alpha, p)
            },
            |(tree, alpha, p)| {
                for pol in [
                    Policy::Pm,
                    Policy::Proportional,
                    Policy::Divisible,
                    Policy::EqualSplit,
                ] {
                    let fast = simulate(tree, *alpha, *p, pol).makespan;
                    let slow = super::simulate_reference(tree, *alpha, *p, pol).makespan;
                    if (fast - slow).abs() > 1e-6 * slow.max(1e-12) {
                        return Err(format!("{pol:?}: fast {fast} vs reference {slow}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn distributed_on_one_node_matches_shared_engine_bitwise() {
        // the whole-tree path is the 1-node special case
        let trees = [
            tree5(),
            TaskTree::from_parents(&[0, 0, 1, 1, 2, 2, 3], &[9.0, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0])
                .unwrap(),
        ];
        for t in &trees {
            for &a in &[0.6, 0.9, 1.0] {
                let p = 10.0;
                let plat = crate::model::Platform::Shared { p };
                let node_of = vec![0usize; t.len()];
                for pol in [Policy::Pm, Policy::Proportional] {
                    let dd = simulate_distributed(t, a, &plat, &node_of, pol);
                    let sd = simulate(t, a, p, pol);
                    assert_eq!(dd.makespan.to_bits(), sd.makespan.to_bits());
                    assert_eq!(dd.events, sd.events);
                    assert_eq!(dd.cross_edges, 0);
                    assert_eq!(dd.cross_stall, 0.0);
                }
            }
        }
    }

    #[test]
    fn distributed_two_node_star_matches_closed_form() {
        // root with two equal leaf children, one per node: each node
        // runs its leaf at full speed; the root waits for the remote
        // child and then runs on node 0
        let t = TaskTree::from_parents(&[0, 0, 0], &[2.0, 8.0, 8.0]).unwrap();
        let (a, p) = (0.5, 4.0);
        let plat = crate::model::Platform::Homogeneous { nodes: 2, p };
        let node_of = vec![0usize, 0, 1];
        let r = simulate_distributed(&t, a, &plat, &node_of, Policy::Pm);
        // leaves: 8 / 4^0.5 = 4 each (full node); root: +2/2 = 1
        assert!(approx_eq(r.completion[1], 4.0, 1e-9));
        assert!(approx_eq(r.completion[2], 4.0, 1e-9));
        assert!(approx_eq(r.makespan, 5.0, 1e-9));
        assert_eq!(r.cross_edges, 1);
        // both children finish at the same instant: no extra stall
        assert!(r.cross_stall.abs() < 1e-12);
        assert!(approx_eq(r.node_finish[0], 5.0, 1e-9));
        assert!(approx_eq(r.node_finish[1], 4.0, 1e-9));
    }

    #[test]
    fn distributed_stall_accounts_remote_wait() {
        // unbalanced split: node 1 gets the long leaf, the root (node
        // 0, with a short local leaf) must stall for the remote one
        let t = TaskTree::from_parents(&[0, 0, 0], &[2.0, 1.0, 16.0]).unwrap();
        let (a, p) = (1.0, 2.0);
        let plat = crate::model::Platform::Homogeneous { nodes: 2, p };
        let node_of = vec![0usize, 0, 1];
        let r = simulate_distributed(&t, a, &plat, &node_of, Policy::Pm);
        // node 0: leaf of len 1 alone -> 0.5; node 1: 16/2 = 8
        assert!(approx_eq(r.completion[1], 0.5, 1e-9));
        assert!(approx_eq(r.completion[2], 8.0, 1e-9));
        // root waits for the remote child: stall = 8 - 0.5
        assert!(approx_eq(r.cross_stall, 7.5, 1e-9));
        assert!(approx_eq(r.makespan, 8.0 + 2.0 / 2.0, 1e-9));
    }

    #[test]
    fn traced_engine_derives_exact_spans_and_round_trips() {
        use crate::obs::{chrome_trace, parse_chrome_trace, SpanKind};
        let t = tree5();
        let (a, p) = (0.9, 10.0);
        for pol in [Policy::Pm, Policy::Proportional, Policy::Divisible, Policy::EqualSplit] {
            let base = simulate(&t, a, p, pol);
            let (res, log) = simulate_traced(&t, a, p, pol);
            assert_eq!(res.makespan.to_bits(), base.makespan.to_bits(), "{pol:?}");
            log.validate().unwrap();
            // one Factor span per task, ending exactly at its completion
            let factors: Vec<_> = log.spans_of(SpanKind::Factor).collect();
            assert_eq!(factors.len(), t.len(), "{pol:?}");
            for s in &factors {
                assert_eq!(
                    s.end.to_bits(),
                    res.completion[s.task as usize].to_bits(),
                    "{pol:?}: task {} span end drifted",
                    s.task
                );
                assert!(s.start <= s.end, "{pol:?}");
                assert_eq!(s.flops, t.nodes[s.task as usize].len, "{pol:?}");
            }
            assert!((log.makespan() - res.makespan).abs() < 1e-12, "{pol:?}");
            // the same export path the executor uses round-trips the
            // model-time log bit-exactly
            let back = parse_chrome_trace(&chrome_trace(&log).unwrap()).unwrap();
            assert_eq!(back, log, "{pol:?}");
        }
    }

    #[test]
    fn traced_distributed_stalls_sum_to_cross_stall() {
        use crate::obs::{chrome_trace, parse_chrome_trace, SpanKind};
        // the unbalanced fixture of distributed_stall_accounts_remote_wait
        let t = TaskTree::from_parents(&[0, 0, 0], &[2.0, 1.0, 16.0]).unwrap();
        let (a, p) = (1.0, 2.0);
        let plat = crate::model::Platform::Homogeneous { nodes: 2, p };
        let node_of = vec![0usize, 0, 1];
        let (r, log) = simulate_distributed_traced(&t, a, &plat, &node_of, Policy::Pm);
        log.validate().unwrap();
        assert_eq!(log.workers, 2, "one track per node");
        assert!(approx_eq(log.total(SpanKind::Stall), r.cross_stall, 1e-12));
        for s in log.spans_of(SpanKind::Factor) {
            assert_eq!(s.worker as usize, node_of[s.task as usize], "track != mapping");
        }
        let back = parse_chrome_trace(&chrome_trace(&log).unwrap()).unwrap();
        assert_eq!(back, log);
    }

    #[test]
    fn traced_distributed_matches_engine_randomized() {
        use crate::obs::SpanKind;
        check(
            Config { cases: 15, seed: 77 },
            "distributed trace: Stall durations sum to cross_stall",
            |rng: &mut Rng| {
                let n = rng.range(3, 40);
                let parents: Vec<usize> =
                    (0..n).map(|i| if i == 0 { 0 } else { rng.below(i) }).collect();
                let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(1.0, 100.0)).collect();
                let alpha = rng.range_f64(0.5, 1.0);
                let nodes = rng.range(2, 5);
                let node_of: Vec<usize> = (0..n).map(|_| rng.below(nodes)).collect();
                (TaskTree::from_parents(&parents, &lens).unwrap(), alpha, nodes, node_of)
            },
            |(tree, alpha, nodes, node_of)| {
                let p = 4.0;
                let plat = crate::model::Platform::Homogeneous { nodes: *nodes, p };
                for pol in [Policy::Pm, Policy::Proportional] {
                    let r = simulate_distributed(tree, *alpha, &plat, node_of, pol);
                    let (rt, log) = simulate_distributed_traced(tree, *alpha, &plat, node_of, pol);
                    if rt.makespan.to_bits() != r.makespan.to_bits() {
                        return Err(format!("{pol:?}: tracing changed the simulation"));
                    }
                    log.validate().map_err(|e| e.to_string())?;
                    let stall: f64 = log.total(SpanKind::Stall);
                    if (stall - r.cross_stall).abs() > 1e-9 * r.cross_stall.max(1.0) {
                        return Err(format!(
                            "{pol:?}: Stall sum {stall} vs cross_stall {}",
                            r.cross_stall
                        ));
                    }
                    if log.spans_of(SpanKind::Factor).count() != tree.len() {
                        return Err(format!("{pol:?}: Factor spans do not cover the tree"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn distributed_beats_pooled_lower_bound_randomized() {
        check(
            Config { cases: 30, seed: 31 },
            "distributed DES >= pooled lower bound",
            |rng: &mut Rng| {
                let n = rng.range(3, 40);
                let parents: Vec<usize> =
                    (0..n).map(|i| if i == 0 { 0 } else { rng.below(i) }).collect();
                let lens: Vec<f64> = (0..n).map(|_| rng.log_uniform(1.0, 100.0)).collect();
                let alpha = rng.range_f64(0.5, 1.0);
                let nodes = rng.range(2, 5);
                let node_of: Vec<usize> = {
                    // random subtree-respecting-ish mapping is not
                    // needed: ANY mapping obeys the pooled bound
                    (0..n).map(|_| rng.below(nodes)).collect()
                };
                (
                    TaskTree::from_parents(&parents, &lens).unwrap(),
                    alpha,
                    nodes,
                    node_of,
                )
            },
            |(tree, alpha, nodes, node_of)| {
                let p = 4.0;
                let plat = crate::model::Platform::Homogeneous { nodes: *nodes, p };
                let g = SpGraph::from_tree(tree);
                let lg = PmSolution::solve(&g, *alpha).total_len;
                let bound = plat.pooled_lower_bound(lg, *alpha);
                for pol in [Policy::Pm, Policy::Proportional] {
                    let r = simulate_distributed(tree, *alpha, &plat, node_of, pol);
                    if r.makespan < bound * (1.0 - 1e-9) {
                        return Err(format!(
                            "{pol:?}: makespan {} below pooled bound {bound}",
                            r.makespan
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn equal_split_handles_chains() {
        let t = TaskTree::from_parents(&[0, 0, 1], &[1.0, 1.0, 1.0]).unwrap();
        let r = simulate(&t, 1.0, 2.0, Policy::EqualSplit);
        // chain of 3 tasks, each alone when ready: 3 * (1/2)
        assert!(approx_eq(r.makespan, 1.5, 1e-9));
        // completions are ordered by precedence
        assert!(r.completion[2] <= r.completion[1]);
        assert!(r.completion[1] <= r.completion[0]);
    }

    #[test]
    fn zero_length_tasks_complete_instantly() {
        let t = TaskTree::from_parents(&[0, 0, 0], &[0.0, 1.0, 1.0]).unwrap();
        let r = simulate(&t, 0.9, 4.0, Policy::EqualSplit);
        assert!(r.makespan > 0.0);
        assert!(approx_eq(r.completion[0], r.makespan, 1e-12));
    }

    #[test]
    fn replay_accounts_full_work() {
        let t = tree5();
        let a = 0.8;
        let pr = Profile::constant(6.0);
        let pm = crate::sched::pm::PmSchedule::for_tree(&t, a, &pr);
        let work = replay_schedule(&t, &pm.schedule, a, &pr);
        for (i, node) in t.nodes.iter().enumerate() {
            assert!(
                approx_eq(work[i], node.len, 1e-6),
                "task {i}: work {} != len {}",
                work[i],
                node.len
            );
        }
    }
}
