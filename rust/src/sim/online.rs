//! Deterministic DES replay of the online service (DESIGN.md §14).
//!
//! [`simulate_online`] drives an [`OnlineService`] over a job stream:
//! at every arrival, completion, deadline and deferred-retry instant it
//! advances remaining work under the current shares, lets the service
//! settle outcomes, and re-solves the share split. The replay is
//! deterministic (same jobs + config → bit-identical report) and
//! *conservative*: every submitted job ends in exactly one of
//! completed / shed / timed-out — the property tests below check this
//! plus termination over randomized seeds, and the overload test pins
//! the headline guarantee (admitted p99 sojourn stays bounded at 2×
//! capacity while a no-admission baseline diverges).

use anyhow::{bail, Context, Result};

use crate::metrics::stats::{mean, quantile};
use crate::online::{Admission, JobSpec, OnlineService, Outcome, ServiceConfig};

use super::event::EventHeap;

/// Aggregate report of one online run.
#[derive(Debug, Clone)]
pub struct OnlineReport {
    pub submitted: usize,
    pub completed: usize,
    pub shed: usize,
    pub timed_out: usize,
    /// Time of the last event.
    pub horizon: f64,
    /// Completed jobs per unit time over the horizon.
    pub throughput: f64,
    /// Sojourn (finish − arrival) quantiles over *completed* jobs
    /// (0 when nothing completed).
    pub p50_sojourn: f64,
    pub p99_sojourn: f64,
    pub mean_sojourn: f64,
    pub max_sojourn: f64,
    /// Fraction of admitted (non-shed) jobs that completed rather than
    /// timing out (1 when nothing was admitted).
    pub slo_attainment: f64,
    pub events: usize,
    pub resolves: usize,
    pub reroundings: usize,
    pub max_queue: usize,
    pub degraded: usize,
    pub deferred: usize,
    /// Terminal state per job id.
    pub outcomes: Vec<Outcome>,
    /// Sojourns of completed jobs (submission order).
    pub sojourns: Vec<f64>,
}

impl OnlineReport {
    /// The conservation invariant: every job has exactly one outcome.
    pub fn conserved(&self) -> bool {
        self.completed + self.shed + self.timed_out == self.submitted
            && self.outcomes.len() == self.submitted
    }
}

/// Replay `jobs` (sorted by arrival; dense ids `0..n`) through a fresh
/// service. Errors on invalid configs and on event-budget exhaustion
/// (the no-deadlock guard), never panics.
pub fn simulate_online(jobs: &[JobSpec], cfg: ServiceConfig) -> Result<OnlineReport> {
    for (i, j) in jobs.iter().enumerate() {
        if j.id != i {
            bail!("job ids must be dense submission indices (job {i} has id {})", j.id);
        }
        if i > 0 && j.arrival < jobs[i - 1].arrival {
            bail!("jobs must be sorted by arrival (job {i} arrives before job {})", i - 1);
        }
    }
    let mut svc = OnlineService::new(cfg)?;
    // deferred re-admissions, keyed by retry time (FIFO among ties —
    // jobs are pushed in submission order, so ties resolve by id)
    let mut retries: EventHeap<usize> = EventHeap::new();
    let mut finish = vec![f64::NAN; jobs.len()];
    let mut t = 0.0f64;
    let mut next_job = 0usize;
    let mut events = 0usize;
    // Each job generates at most: 1 arrival, max_retries retries, 1
    // completion/expiry — plus a resolve-driven completion chain per
    // slot. A generous multiple is a pure deadlock backstop.
    let budget = 16 * (jobs.len() + 1) * (2 + svc.config().defer.max_retries);

    loop {
        let t_arrival =
            if next_job < jobs.len() { jobs[next_job].arrival } else { f64::INFINITY };
        let t_retry = retries.peek_time().unwrap_or(f64::INFINITY);
        let t_deadline = svc.next_deadline();
        let t_complete = svc.next_completion().map_or(f64::INFINITY, |(dt, _)| t + dt);
        let t_next = t_arrival.min(t_retry).min(t_deadline).min(t_complete);
        if !t_next.is_finite() {
            break; // no arrivals, retries or live work left
        }
        events += 1;
        if events > budget {
            bail!(
                "online replay exceeded its event budget ({budget}) at t={t}: \
                 {} running, {} queued, {} retries pending — scheduler deadlock",
                svc.running_len(),
                svc.queue_len(),
                retries.len()
            );
        }
        svc.advance((t_next - t).max(0.0));
        t = t_next;
        let mut changed = false;
        // completions first: a job finishing exactly at its deadline counts
        for id in svc.reap() {
            finish[id] = t;
            changed = true;
        }
        // then deadline expiries
        for id in svc.expire(t) {
            finish[id] = t;
            changed = true;
        }
        // arrivals due
        while next_job < jobs.len() && jobs[next_job].arrival <= t {
            let job = &jobs[next_job];
            next_job += 1;
            match svc.submit(t, job) {
                Admission::Admitted => changed = true,
                Admission::Shed => finish[job.id] = t,
                Admission::Deferred { until } => retries.push(until, job.id),
            }
        }
        // deferred retries due
        while retries.peek_time().is_some_and(|at| at <= t) {
            let (_, id) = retries.pop().unwrap();
            match svc.readmit(t, id) {
                Admission::Admitted => changed = true,
                Admission::Shed => finish[id] = t,
                Admission::Deferred { until } => retries.push(until, id),
            }
        }
        if changed {
            svc.resolve();
        }
    }

    let outcomes: Vec<Outcome> = (0..jobs.len())
        .map(|id| svc.outcome(id).with_context(|| format!("job {id} has no outcome")))
        .collect::<Result<_>>()?;
    let sojourns: Vec<f64> = outcomes
        .iter()
        .enumerate()
        .filter(|(_, o)| **o == Outcome::Completed)
        .map(|(id, _)| finish[id] - jobs[id].arrival)
        .collect();
    let s = svc.stats();
    let admitted = s.completed + s.timed_out;
    Ok(OnlineReport {
        submitted: jobs.len(),
        completed: s.completed,
        shed: s.shed,
        timed_out: s.timed_out,
        horizon: t,
        throughput: if t > 0.0 { s.completed as f64 / t } else { 0.0 },
        p50_sojourn: if sojourns.is_empty() { 0.0 } else { quantile(&sojourns, 0.50) },
        p99_sojourn: if sojourns.is_empty() { 0.0 } else { quantile(&sojourns, 0.99) },
        mean_sojourn: if sojourns.is_empty() { 0.0 } else { mean(&sojourns) },
        max_sojourn: sojourns.iter().fold(0.0f64, |a, &b| a.max(b)),
        slo_attainment: if admitted > 0 { s.completed as f64 / admitted as f64 } else { 1.0 },
        events,
        resolves: s.resolves,
        reroundings: s.reroundings,
        max_queue: s.max_queue,
        degraded: s.degraded,
        deferred: s.deferred,
        outcomes,
        sojourns,
    })
}

/// Derive a model-time job timeline from an online run: one track per
/// tenant. A Completed job is a Factor span `[arrival, arrival +
/// sojourn]` (flops = the job tree's total work), a TimedOut job a
/// Stall span from arrival to its explicit deadline (clamped to the
/// horizon; the horizon itself when the deadline was implied), and a
/// Shed job a zero-length Retry marker at its arrival. `jobs` must be
/// the stream the report came from — [`OnlineReport::outcomes`] and
/// [`OnlineReport::sojourns`] are consumed by job id.
pub fn trace_online(jobs: &[JobSpec], report: &OnlineReport) -> crate::obs::TraceLog {
    use crate::obs::{Span, SpanKind, TimeUnit, TraceLog};
    assert_eq!(jobs.len(), report.outcomes.len(), "report does not match the job stream");
    let tenants = jobs.iter().map(|j| j.tenant).max().map_or(1, |t| t + 1);
    let mut log = TraceLog::new("sim-online", TimeUnit::Model, tenants);
    let mut sojourn = report.sojourns.iter();
    for job in jobs {
        let work: f64 = job.tree.nodes.iter().map(|t| t.len).sum();
        let span = match report.outcomes[job.id] {
            Outcome::Completed => {
                let s = *sojourn.next().expect("fewer sojourns than completed jobs");
                Span {
                    kind: SpanKind::Factor,
                    task: job.id as u32,
                    worker: job.tenant as u32,
                    team: 0.0,
                    flops: work,
                    start: job.arrival,
                    end: job.arrival + s.max(0.0),
                }
            }
            Outcome::TimedOut => {
                let end = if job.deadline.is_finite() {
                    job.deadline.min(report.horizon)
                } else {
                    report.horizon
                };
                Span {
                    kind: SpanKind::Stall,
                    task: job.id as u32,
                    worker: job.tenant as u32,
                    team: 0.0,
                    flops: work,
                    start: job.arrival,
                    end: end.max(job.arrival),
                }
            }
            Outcome::Shed => Span {
                kind: SpanKind::Retry,
                task: job.id as u32,
                worker: job.tenant as u32,
                team: 0.0,
                flops: work,
                start: job.arrival,
                end: job.arrival,
            },
        };
        log.push(span);
    }
    log.sort();
    log
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::online::{job_stream, FairnessMode, OverloadPolicy, StreamSpec};
    use crate::util::prop;
    use crate::util::retry::LinearBackoff;
    use crate::util::rng::Rng;
    use crate::workload::generator::ArrivalProcess;

    fn stream(rng: &mut Rng, jobs: usize, min_nodes: usize, max_nodes: usize) -> Vec<JobSpec> {
        let spec = StreamSpec {
            jobs,
            tenants: 1 + rng.below(4),
            min_nodes,
            max_nodes,
            seed: rng.next_u64(),
        };
        let process = match rng.below(3) {
            0 => ArrivalProcess::Poisson { rate: rng.range_f64(0.5, 8.0) },
            1 => ArrivalProcess::Bursty { rate: rng.range_f64(0.5, 8.0), burst: 4.0 },
            _ => ArrivalProcess::HeavyTailed { rate: rng.range_f64(0.5, 8.0), shape: 2.5 },
        };
        job_stream(process, &spec)
    }

    fn random_config(rng: &mut Rng) -> ServiceConfig {
        ServiceConfig {
            alpha: [0.7, 0.9, 1.0][rng.below(3)],
            p: [2, 4, 8][rng.below(3)],
            queue_cap: [0, 2, 8][rng.below(3)],
            deadline_ratio: [1.5, 4.0, f64::INFINITY][rng.below(3)],
            mode: if rng.bool(0.5) { FairnessMode::WeightedFair } else { FairnessMode::Makespan },
            overload: [OverloadPolicy::Reject, OverloadPolicy::Defer, OverloadPolicy::Degrade]
                [rng.below(3)],
            defer: LinearBackoff::new(rng.range_f64(0.0, 1.0), rng.below(4)),
            degrade_factor: 0.5,
        }
    }

    #[test]
    fn every_job_is_conserved_and_the_replay_terminates() {
        prop::check(
            prop::Config { cases: 24, seed: 0x0115E },
            "online-conservation",
            |rng| {
                let n = 20 + rng.below(30);
                let mut jobs = stream(rng, n, 3, 15);
                // inject a zero-work single-task job mid-stream: it must
                // complete instantly without deadline pathology
                let mid = jobs.len() / 2;
                for node in jobs[mid].tree.nodes.iter_mut() {
                    node.len = 0.0;
                }
                (jobs, random_config(rng))
            },
            |(jobs, cfg)| {
                let report = simulate_online(jobs, cfg.clone())
                    .map_err(|e| format!("replay failed: {e:#}"))?;
                if !report.conserved() {
                    return Err(format!(
                        "not conserved: {} + {} + {} != {}",
                        report.completed, report.shed, report.timed_out, report.submitted
                    ));
                }
                if report.sojourns.iter().any(|&s| !(s >= 0.0)) {
                    return Err(format!("negative sojourn in {:?}", report.sojourns));
                }
                // the zero-work job has no implied deadline (t_iso = 0)
                // so it may be shed, never timed out
                let mid = jobs.len() / 2;
                if report.outcomes[mid] == Outcome::TimedOut {
                    return Err("zero-work job timed out".to_string());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn online_trace_covers_every_job_exactly_once() {
        use crate::obs::{chrome_trace, parse_chrome_trace, SpanKind};
        let mut rng = Rng::new(0x0B51);
        // tight capacity + deadlines so all three outcomes can occur
        let jobs = stream(&mut rng, 60, 3, 14);
        let cfg = ServiceConfig {
            p: 2,
            queue_cap: 2,
            deadline_ratio: 1.5,
            ..ServiceConfig::default()
        };
        let rep = simulate_online(&jobs, cfg).unwrap();
        assert!(rep.conserved());
        let log = trace_online(&jobs, &rep);
        log.validate().unwrap();
        // one span per job, kind matching its terminal outcome
        assert_eq!(log.spans.len(), jobs.len());
        assert_eq!(log.spans_of(SpanKind::Factor).count(), rep.completed);
        assert_eq!(log.spans_of(SpanKind::Stall).count(), rep.timed_out);
        assert_eq!(log.spans_of(SpanKind::Retry).count(), rep.shed);
        assert!(rep.completed > 0, "fixture completed nothing");
        // completed spans replay the recorded sojourns exactly
        let mut sojourns: Vec<f64> = log
            .spans_of(SpanKind::Factor)
            .map(|s| s.end - s.start)
            .collect();
        sojourns.sort_by(f64::total_cmp);
        let mut want = rep.sojourns.clone();
        want.sort_by(f64::total_cmp);
        for (a, b) in sojourns.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12, "sojourn {a} vs {b}");
        }
        assert!(log.makespan() <= rep.horizon + 1e-9);
        // tenant tracks + bit-exact export round-trip
        for s in &log.spans {
            assert_eq!(s.worker as usize, jobs[s.task as usize].tenant);
        }
        let back = parse_chrome_trace(&chrome_trace(&log).unwrap()).unwrap();
        assert_eq!(back, log);
    }

    #[test]
    fn replay_is_deterministic() {
        let mut rng = Rng::new(0xD5);
        let jobs = stream(&mut rng, 40, 3, 12);
        let cfg = ServiceConfig { p: 4, queue_cap: 2, ..ServiceConfig::default() };
        let a = simulate_online(&jobs, cfg.clone()).unwrap();
        let b = simulate_online(&jobs, cfg).unwrap();
        assert_eq!(a.events, b.events);
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.horizon.to_bits(), b.horizon.to_bits());
        assert_eq!(
            a.sojourns.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            b.sojourns.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn rejects_unsorted_or_misnumbered_streams() {
        let mut rng = Rng::new(3);
        let mut jobs = stream(&mut rng, 8, 3, 8);
        jobs.swap(2, 5);
        assert!(simulate_online(&jobs, ServiceConfig::default()).is_err());
        let mut jobs = stream(&mut rng, 4, 3, 8);
        jobs[1].id = 7;
        assert!(simulate_online(&jobs, ServiceConfig::default()).is_err());
    }

    /// The headline overload guarantee: at λ = 2× capacity, admission
    /// control sheds load and keeps the p99 sojourn of *admitted* jobs
    /// under the structural bound `deadline_ratio · max T_iso`, while a
    /// no-admission baseline admits everything and its p99 diverges.
    #[test]
    fn overload_keeps_admitted_p99_bounded_while_baseline_diverges() {
        let alpha = 0.9;
        let p = 8usize;
        let spec = StreamSpec { jobs: 240, tenants: 4, min_nodes: 20, max_nodes: 30, seed: 0xBEEF };
        // calibrate the arrival rate to 2× the service capacity
        // p / mean(L): each job needs at least L/p^α·p^α = L CPU-time
        let probe = job_stream(ArrivalProcess::Poisson { rate: 1.0 }, &spec);
        let mean_work: f64 = probe.iter().map(|j| j.tree.total_work()).sum::<f64>()
            / probe.len() as f64;
        let capacity = p as f64 / mean_work;
        let jobs = job_stream(ArrivalProcess::Poisson { rate: 2.0 * capacity }, &spec);
        let ratio = 6.0;
        let admitted_cfg = ServiceConfig {
            alpha,
            p,
            queue_cap: 8,
            deadline_ratio: ratio,
            overload: OverloadPolicy::Reject,
            ..ServiceConfig::default()
        };
        let baseline_cfg = ServiceConfig {
            alpha,
            p,
            queue_cap: usize::MAX,
            deadline_ratio: f64::INFINITY,
            overload: OverloadPolicy::Reject,
            ..ServiceConfig::default()
        };
        let admitted = simulate_online(&jobs, admitted_cfg).unwrap();
        let baseline = simulate_online(&jobs, baseline_cfg).unwrap();
        assert!(admitted.conserved() && baseline.conserved());
        assert!(admitted.shed > 0, "2× overload must shed ({} shed)", admitted.shed);
        assert!(admitted.completed > 0, "some jobs must still complete");
        // structural bound: an admitted job finishes (or is cancelled)
        // within deadline_ratio × its isolated runtime
        let max_t_iso = jobs
            .iter()
            .map(|j| j.tree.total_work()) // L_G <= Σ L_i, so this over-bounds T_iso·p^α
            .fold(0.0f64, f64::max)
            / (p as f64).powf(alpha);
        let bound = ratio * max_t_iso;
        assert!(
            admitted.p99_sojourn <= bound * (1.0 + 1e-9),
            "admitted p99 {} exceeds the deadline bound {bound}",
            admitted.p99_sojourn
        );
        // the baseline admits everything and completes everything…
        assert_eq!(baseline.shed + baseline.timed_out, 0);
        // …but its tail grows without admission control
        assert!(
            baseline.p99_sojourn > admitted.p99_sojourn,
            "baseline p99 {} should exceed admitted p99 {}",
            baseline.p99_sojourn,
            admitted.p99_sojourn
        );
    }
}
