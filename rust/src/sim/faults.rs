//! Failure/elasticity replay over the distributed DES (DESIGN.md §13).
//!
//! [`replay_faults_distributed`] replays a static-share schedule (PM
//! or proportional, as [`super::des::simulate_distributed`]) while a
//! [`FaultTrace`] disturbs the platform. The engine is segmented: it
//! runs the ordinary time-keyed completion heap up to the next
//! disturbance, charges partial progress to every running task, applies
//! the event, re-solves the per-node shares over the *remaining*
//! forest (the malleable model makes every event a cheap re-solve) and
//! continues. With an empty trace it delegates to the fault-free
//! engine, so fault-free replay is bit-identical by construction.
//!
//! **Tie-break.** A disturbance landing exactly on a task boundary
//! processes the completion first: the segment drains every heap event
//! with `t <= event.time` before the event applies. A crash at the
//! instant a remote subtree finishes therefore loses nothing — its
//! parent has already consumed the contribution (deterministic, see
//! the boundary tests).
//!
//! **Crash semantics.** A crash kills a node permanently. Results are
//! lost by *residency*: a completed task's contribution block lives on
//! its own node until the parent **starts** (assembly consumes it);
//! survivors keep consumed contributions inside their running fronts.
//! So the lost set is: every incomplete task of the dead node, plus
//! every completed task (on any node) whose parent has not started,
//! whose block lived on the dead node, plus — recursively — completed
//! dead-node children of lost dead-node parents (the re-run parent
//! must re-consume them). Lost components are either re-mapped onto
//! survivors ([`crate::dist::mapping::remap_lost`]) or the whole tree
//! restarts from scratch on the surviving platform; under
//! [`RecoveryPolicy::Best`] both candidates are evaluated by an exact
//! run-to-completion lookahead and the better one is kept, so
//! re-mapped recovery is never worse than restart by construction
//! (the PR 4 candidate-selection pattern).

use anyhow::{bail, Result};

use crate::dist::mapping::{map_tree, remap_lost, MappingStrategy};
use crate::model::{FaultKind, FaultTrace, Platform, TaskTree};
use crate::sched::SchedWorkspace;

use super::des::{simulate_distributed_with_workspace, speedup, Policy};
use super::event::EventHeap;

/// How a crash is recovered.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryPolicy {
    /// Evaluate re-map and restart by lookahead, keep the better —
    /// never worse than either alternative alone.
    Best,
    /// Always re-map lost components onto survivors.
    RemapOnly,
    /// Always restart the whole tree on the surviving platform (the
    /// checkpoint-free baseline).
    RestartOnly,
}

/// Result of a fault replay.
#[derive(Debug, Clone)]
pub struct FaultReplay {
    /// Makespan under the disturbance trace.
    pub makespan: f64,
    /// Final completion time per task (re-run tasks carry their last
    /// completion).
    pub completion: Vec<f64>,
    /// DES completion events processed (re-runs count again).
    pub events: usize,
    /// Disturbance events applied.
    pub fault_events: usize,
    /// Work units destroyed by crashes (and restarts).
    pub lost_work: f64,
    /// Lost components re-mapped onto survivors.
    pub remapped_subtrees: usize,
    /// Whether any crash was recovered by restart-from-scratch.
    pub restarted: bool,
    /// Makespan of the same schedule with no disturbance.
    pub fault_free_makespan: f64,
    /// Final task → node assignment (after any re-mapping).
    pub node_of: Vec<usize>,
}

impl FaultReplay {
    /// Absolute recovery overhead over the fault-free run.
    pub fn recovery_overhead(&self) -> f64 {
        self.makespan - self.fault_free_makespan
    }
}

/// Shared-memory fault replay: one node of `p` processors. Crashes are
/// rejected by validation (the only node must survive); leave/join and
/// slowdown events model elastic capacity.
pub fn replay_faults(
    tree: &TaskTree,
    alpha: f64,
    p: f64,
    policy: Policy,
    trace: &FaultTrace,
) -> Result<FaultReplay> {
    let platform = Platform::Shared { p };
    let node_of = vec![0usize; tree.len()];
    replay_faults_distributed(tree, alpha, &platform, &node_of, policy, trace, RecoveryPolicy::Best)
}

/// Mutable replay state — cloneable so recovery candidates can be
/// evaluated by lookahead without committing.
#[derive(Clone)]
struct EngineState {
    node_of: Vec<usize>,
    cores: Vec<f64>,
    slow: Vec<f64>,
    alive: Vec<bool>,
    remaining: Vec<f64>,
    completed: Vec<bool>,
    /// Pushed to the completion heap at some point: the task began and
    /// its children's contribution blocks were consumed.
    started: Vec<bool>,
    completion: Vec<f64>,
    unfinished: Vec<usize>,
    ready_all: Vec<f64>,
    events: usize,
    lost_work: f64,
    remapped: usize,
    restarted: bool,
}

/// Internal (expanded) disturbance: slowdowns become a set/clear pair.
enum Dist {
    Crash(usize),
    Leave(usize, f64),
    Join(usize, f64),
    SlowSet(usize, f64),
    SlowClear(usize),
}

struct Timed {
    time: f64,
    what: Dist,
    /// Counts toward `fault_events` (slowdown-clear markers do not).
    counted: bool,
}

/// Per-node static shares over the remaining (incomplete) forest —
/// the exact float path of the distributed engine, restricted to alive
/// nodes at their current capacity.
fn solve_shares(
    tree2: &TaskTree,
    alpha: f64,
    policy: Policy,
    st: &EngineState,
    ws: &mut SchedWorkspace,
) -> Vec<f64> {
    let n = tree2.len();
    let mut share = vec![0f64; n];
    let mut member = vec![false; n];
    for k in 0..st.alive.len() {
        if !st.alive[k] {
            continue;
        }
        for (t, m) in member.iter_mut().enumerate() {
            *m = !st.completed[t] && st.node_of[t] == k;
        }
        let p_k = st.cores[k] * st.slow[k];
        match policy {
            Policy::Pm => {
                if let Some(r) = ws.induced_task_ratios(tree2, &member, alpha, n) {
                    for t in 0..n {
                        if member[t] {
                            share[t] = r[t] * p_k;
                        }
                    }
                }
            }
            Policy::Proportional => {
                if let Some(g) = crate::model::SpGraph::from_induced(tree2, &member) {
                    let shares = crate::sched::proportional::proportional_shares(&g, p_k);
                    for &v in g.topo() {
                        if let crate::model::SpNode::Leaf { task: Some(t), .. } =
                            g.nodes[v as usize]
                        {
                            let ratio = shares[v as usize] / p_k;
                            share[t as usize] = ratio * p_k;
                        }
                    }
                }
            }
            _ => unreachable!(),
        }
    }
    share
}

/// Run the completion heap from `t_start` up to `until` (inclusive —
/// the boundary tie-break), or to exhaustion when `None`. Charges
/// partial progress to still-running tasks at the cut.
#[allow(clippy::too_many_arguments)]
fn run_segment(
    tree: &TaskTree,
    tree2: &mut TaskTree,
    alpha: f64,
    policy: Policy,
    ws: &mut SchedWorkspace,
    st: &mut EngineState,
    t_start: f64,
    until: Option<f64>,
) {
    let n = tree.len();
    for v in 0..n {
        tree2.nodes[v].len = st.remaining[v];
    }
    let share = solve_shares(tree2, alpha, policy, st, ws);
    let len_now = st.remaining.clone();
    let dur = |v: u32| -> f64 {
        let len = len_now[v as usize];
        if len <= 0.0 {
            0.0
        } else {
            len / speedup(share[v as usize], alpha)
        }
    };
    let mut heap: EventHeap<u32> = EventHeap::with_capacity(n);
    let mut run_since = vec![t_start; n];
    let mut in_heap = vec![false; n];
    for v in 0..n as u32 {
        let vi = v as usize;
        if !st.completed[vi] && st.unfinished[vi] == 0 {
            heap.push(t_start + dur(v), v);
            in_heap[vi] = true;
            st.started[vi] = true;
        }
    }
    while let Some((t, v)) = heap.peek() {
        if let Some(u) = until {
            if t > u {
                break;
            }
        }
        heap.pop();
        st.events += 1;
        let vi = v as usize;
        in_heap[vi] = false;
        st.completed[vi] = true;
        st.remaining[vi] = 0.0;
        st.completion[vi] = t;
        if let Some(parent) = tree.nodes[vi].parent {
            let pi = parent as usize;
            st.unfinished[pi] -= 1;
            st.ready_all[pi] = st.ready_all[pi].max(t);
            if st.unfinished[pi] == 0 {
                st.started[pi] = true;
                run_since[pi] = st.ready_all[pi];
                in_heap[pi] = true;
                heap.push(st.ready_all[pi] + dur(parent), parent);
            }
        }
    }
    if let Some(u) = until {
        for v in 0..n {
            if in_heap[v] {
                let done = (u - run_since[v]).max(0.0) * speedup(share[v], alpha);
                st.remaining[v] = (st.remaining[v] - done).max(0.0);
            }
        }
    }
}

/// Recompute dependency counters and ready times from the completion
/// flags (after a crash reset rewires them wholesale).
fn rebuild_dependencies(tree: &TaskTree, st: &mut EngineState) {
    let n = tree.len();
    for v in 0..n {
        st.unfinished[v] = 0;
        st.ready_all[v] = 0.0;
    }
    for v in 0..n {
        if let Some(p) = tree.nodes[v].parent {
            let pi = p as usize;
            if st.completed[v] {
                st.ready_all[pi] = st.ready_all[pi].max(st.completion[v]);
            } else {
                st.unfinished[pi] += 1;
            }
        }
    }
}

/// Run a candidate state to completion and report its makespan (exact
/// when the crash is the last disturbance; a lookahead bound
/// otherwise).
fn lookahead(
    tree: &TaskTree,
    alpha: f64,
    policy: Policy,
    ws: &mut SchedWorkspace,
    st: &EngineState,
    t_now: f64,
) -> f64 {
    let mut s = st.clone();
    let mut scratch = tree.clone();
    run_segment(tree, &mut scratch, alpha, policy, ws, &mut s, t_now, None);
    s.completion.iter().fold(0.0f64, |a, &b| a.max(b))
}

/// Kill `node` at time `at`: compute the lost set, reset it, and
/// recover per `recovery` (re-map vs restart candidates).
#[allow(clippy::too_many_arguments)]
fn apply_crash(
    tree: &TaskTree,
    alpha: f64,
    policy: Policy,
    ws: &mut SchedWorkspace,
    st: &mut EngineState,
    node: usize,
    at: f64,
    recovery: RecoveryPolicy,
) -> Result<()> {
    st.alive[node] = false;
    if !st.alive.iter().any(|&a| a) {
        bail!("all nodes crashed by t={at}");
    }
    let n = tree.len();
    // Lost set, parents before children so the recursive residency
    // rule sees the parent's fate first.
    let mut needed = vec![false; n];
    for &v in &tree.topo_down() {
        let vi = v as usize;
        if st.node_of[vi] != node {
            continue;
        }
        needed[vi] = if !st.completed[vi] {
            true
        } else {
            match tree.nodes[vi].parent {
                None => false,
                Some(p) => {
                    let pi = p as usize;
                    // block still resident (parent never consumed it),
                    // or a lost dead-node parent must re-consume it
                    !st.started[pi] || (st.node_of[pi] == node && needed[pi])
                }
            }
        };
    }
    let lost: f64 = (0..n)
        .filter(|&v| needed[v])
        .map(|v| tree.nodes[v].len - st.remaining[v])
        .sum();
    st.lost_work += lost;
    for v in 0..n {
        if needed[v] {
            st.remaining[v] = tree.nodes[v].len;
            st.completed[v] = false;
            st.started[v] = false;
            st.completion[v] = 0.0;
        }
    }
    rebuild_dependencies(tree, st);

    // Candidate A: re-map lost components onto the least-busy
    // survivors (power-space LPT seeded with survivor residuals).
    let inv = 1.0 / alpha;
    let mut node_load = vec![0f64; st.alive.len()];
    for v in 0..n {
        if !st.completed[v] && !needed[v] {
            node_load[st.node_of[v]] += st.remaining[v].max(0.0).powf(inv);
        }
    }
    let comps = remap_lost(tree, &needed, &st.remaining, alpha, &st.alive, &st.cores, &node_load)?;
    let mut remapped = st.clone();
    for &(root, k) in &comps {
        let mut stack = vec![root];
        while let Some(t) = stack.pop() {
            let ti = t as usize;
            remapped.node_of[ti] = k;
            for &c in &tree.nodes[ti].children {
                if needed[c as usize] {
                    stack.push(c);
                }
            }
        }
    }
    remapped.remapped += comps.len();

    // Candidate B: restart from scratch — discard all progress and
    // re-map the whole tree onto the surviving platform.
    let mut restart = st.clone();
    let extra: f64 = (0..n).map(|v| tree.nodes[v].len - restart.remaining[v]).sum();
    restart.lost_work += extra;
    let alive_ids: Vec<usize> = (0..st.alive.len()).filter(|&k| st.alive[k]).collect();
    let speeds: Vec<f64> = alive_ids.iter().map(|&k| st.cores[k]).collect();
    let survivors = Platform::Heterogeneous { speeds };
    let fresh = map_tree(tree, &survivors, alpha, MappingStrategy::Pm, 1.1);
    for v in 0..n {
        restart.node_of[v] = alive_ids[fresh.node_of[v]];
        restart.remaining[v] = tree.nodes[v].len;
        restart.completed[v] = false;
        restart.started[v] = false;
        restart.completion[v] = 0.0;
    }
    rebuild_dependencies(tree, &mut restart);
    restart.restarted = true;

    *st = match recovery {
        RecoveryPolicy::RemapOnly => remapped,
        RecoveryPolicy::RestartOnly => restart,
        RecoveryPolicy::Best => {
            let ma = lookahead(tree, alpha, policy, ws, &remapped, at);
            let mb = lookahead(tree, alpha, policy, ws, &restart, at);
            if ma <= mb {
                remapped
            } else {
                restart
            }
        }
    };
    Ok(())
}

/// Replay a distributed static-share schedule under `trace`,
/// recovering crashes per `recovery`. With an empty trace this
/// delegates to [`super::des::simulate_distributed`] — bit-identical
/// fault-free behaviour by construction.
pub fn replay_faults_distributed(
    tree: &TaskTree,
    alpha: f64,
    platform: &Platform,
    node_of: &[usize],
    policy: Policy,
    trace: &FaultTrace,
    recovery: RecoveryPolicy,
) -> Result<FaultReplay> {
    let n = tree.len();
    let n_nodes = platform.num_nodes();
    if node_of.len() != n {
        bail!("node_of covers {} tasks, tree has {n}", node_of.len());
    }
    for &k in node_of {
        if k >= n_nodes {
            bail!("task mapped to node {k}, platform has {n_nodes} nodes");
        }
    }
    if !matches!(policy, Policy::Pm | Policy::Proportional) {
        bail!("fault replay supports static-share policies (Pm, Proportional), got {policy:?}");
    }
    trace.validate(n_nodes)?;

    let mut ws = SchedWorkspace::new();
    let base = simulate_distributed_with_workspace(tree, alpha, platform, node_of, policy, &mut ws);
    let fault_free = base.makespan;
    if trace.is_empty() {
        return Ok(FaultReplay {
            makespan: base.makespan,
            completion: base.completion,
            events: base.events,
            fault_events: 0,
            lost_work: 0.0,
            remapped_subtrees: 0,
            restarted: false,
            fault_free_makespan: fault_free,
            node_of: node_of.to_vec(),
        });
    }

    let mut timed: Vec<Timed> = Vec::with_capacity(trace.len() * 2);
    for e in &trace.events {
        match e.kind {
            FaultKind::Crash { node } => {
                timed.push(Timed { time: e.time, what: Dist::Crash(node), counted: true });
            }
            FaultKind::Leave { node, cores } => {
                timed.push(Timed { time: e.time, what: Dist::Leave(node, cores), counted: true });
            }
            FaultKind::Join { node, cores } => {
                timed.push(Timed { time: e.time, what: Dist::Join(node, cores), counted: true });
            }
            FaultKind::Slowdown { node, factor, duration } => {
                timed.push(Timed { time: e.time, what: Dist::SlowSet(node, factor), counted: true });
                timed.push(Timed {
                    time: e.time + duration,
                    what: Dist::SlowClear(node),
                    counted: false,
                });
            }
            // link faults disturb the network, not the compute nodes;
            // this replay prices every transfer at zero, so they are
            // no-ops here (the priced engine in `crate::net` replays
            // them) — skipping keeps compute-only traces bit-identical
            FaultKind::LinkDegrade { .. } | FaultKind::LinkDown { .. } => {}
        }
    }
    timed.sort_by(|a, b| a.time.total_cmp(&b.time));

    let mut st = EngineState {
        node_of: node_of.to_vec(),
        cores: (0..n_nodes).map(|k| platform.node_cores(k)).collect(),
        slow: vec![1.0; n_nodes],
        alive: vec![true; n_nodes],
        remaining: tree.nodes.iter().map(|t| t.len).collect(),
        completed: vec![false; n],
        started: vec![false; n],
        completion: vec![0f64; n],
        unfinished: tree.nodes.iter().map(|t| t.children.len()).collect(),
        ready_all: vec![0f64; n],
        events: 0,
        lost_work: 0.0,
        remapped: 0,
        restarted: false,
    };
    let mut tree2 = tree.clone();
    let mut t_now = 0.0f64;
    let mut fault_events = 0usize;
    for ev in &timed {
        run_segment(tree, &mut tree2, alpha, policy, &mut ws, &mut st, t_now, Some(ev.time));
        t_now = t_now.max(ev.time);
        if st.completed.iter().all(|&c| c) {
            break;
        }
        if ev.counted {
            fault_events += 1;
        }
        match ev.what {
            Dist::Crash(k) => {
                if st.alive[k] {
                    apply_crash(tree, alpha, policy, &mut ws, &mut st, k, ev.time, recovery)?;
                }
            }
            Dist::Leave(k, c) => {
                if st.alive[k] {
                    st.cores[k] -= c;
                    if st.cores[k] <= 1e-12 {
                        bail!("node {k} has no cores left at t={}", ev.time);
                    }
                }
            }
            Dist::Join(k, c) => {
                if st.alive[k] {
                    st.cores[k] += c;
                }
            }
            Dist::SlowSet(k, f) => {
                if st.alive[k] {
                    st.slow[k] = f;
                }
            }
            Dist::SlowClear(k) => {
                if st.alive[k] {
                    st.slow[k] = 1.0;
                }
            }
        }
    }
    if !st.completed.iter().all(|&c| c) {
        run_segment(tree, &mut tree2, alpha, policy, &mut ws, &mut st, t_now, None);
    }
    let makespan = st.completion.iter().fold(0.0f64, |a, &b| a.max(b));
    Ok(FaultReplay {
        makespan,
        completion: st.completion,
        events: st.events,
        fault_events,
        lost_work: st.lost_work,
        remapped_subtrees: st.remapped,
        restarted: st.restarted,
        fault_free_makespan: fault_free,
        node_of: st.node_of,
    })
}

/// Derive a model-time [`crate::obs::TraceLog`] from a fault replay:
/// one Factor span per task on its *final* owning node's track
/// ([`FaultReplay::node_of`], after any crash re-mapping), plus a
/// Stall span wherever remote children gate a parent. Shares vary
/// across disturbance segments, so spans carry `team = 0` (unknown);
/// each window is the task's last (post-recovery) execution, ending at
/// its final completion.
pub fn trace_replay(tree: &TaskTree, replay: &FaultReplay) -> crate::obs::TraceLog {
    crate::obs::from_completions(
        "sim-faults",
        tree,
        &replay.completion,
        None,
        None,
        Some(&replay.node_of),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::FaultEvent;
    use crate::sim::des::{simulate, simulate_distributed};
    use crate::sim::memreplay::{replay_memory_spans, spans_from_completions};
    use crate::util::prop::{check, Config};
    use crate::util::rng::Rng;
    use crate::workload::generator::{random_tree, synthetic_mem_weights, TreeClass};

    fn bits(xs: &[f64]) -> Vec<u64> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn empty_trace_matches_shared_engine_bitwise() {
        // the satellite property: fault-free replay IS the fault-free
        // engine, down to the last bit — makespan, completions, event
        // count, and the memory replay derived from the completions
        check(
            Config { cases: 24, seed: 0xFA117 },
            "empty trace == shared DES (bitwise)",
            |rng: &mut Rng| {
                let classes = [TreeClass::Uniform, TreeClass::Deep, TreeClass::Binary];
                let t = random_tree(classes[rng.below(3)], rng.range(2, 120), rng);
                let w = synthetic_mem_weights(&t, rng);
                let alpha = rng.range_f64(0.55, 1.0);
                let p = rng.range_f64(2.0, 32.0);
                let policy = if rng.bool(0.5) { Policy::Pm } else { Policy::Proportional };
                (t, w, alpha, p, policy)
            },
            |(t, w, alpha, p, policy)| {
                let base = simulate(t, *alpha, *p, *policy);
                let f = replay_faults(t, *alpha, *p, *policy, &FaultTrace::empty())
                    .map_err(|e| e.to_string())?;
                if f.makespan.to_bits() != base.makespan.to_bits() {
                    return Err(format!("makespan {} vs {}", f.makespan, base.makespan));
                }
                if bits(&f.completion) != bits(&base.completion) {
                    return Err("completion vectors differ".into());
                }
                if f.events != base.events {
                    return Err(format!("events {} vs {}", f.events, base.events));
                }
                let sa = spans_from_completions(t, &base.completion);
                let sb = spans_from_completions(t, &f.completion);
                let ra = replay_memory_spans(t, w, &sa, None);
                let rb = replay_memory_spans(t, w, &sb, None);
                if ra.peak.to_bits() != rb.peak.to_bits() {
                    return Err(format!("mem peak {} vs {}", ra.peak, rb.peak));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_trace_matches_distributed_engine_bitwise() {
        check(
            Config { cases: 16, seed: 0xFA118 },
            "empty trace == distributed DES (bitwise)",
            |rng: &mut Rng| {
                let t = random_tree(TreeClass::Uniform, rng.range(4, 150), rng);
                let alpha = rng.range_f64(0.55, 1.0);
                let nodes = rng.range(2, 5);
                let p = rng.range_f64(2.0, 16.0);
                let plat = Platform::Homogeneous { nodes, p };
                let m = map_tree(&t, &plat, alpha, MappingStrategy::Pm, 1.1);
                (t, alpha, plat, m.node_of)
            },
            |(t, alpha, plat, node_of)| {
                let base = simulate_distributed(t, *alpha, plat, node_of, Policy::Pm);
                let f = replay_faults_distributed(
                    t,
                    *alpha,
                    plat,
                    node_of,
                    Policy::Pm,
                    &FaultTrace::empty(),
                    RecoveryPolicy::Best,
                )
                .map_err(|e| e.to_string())?;
                if f.makespan.to_bits() != base.makespan.to_bits()
                    || bits(&f.completion) != bits(&base.completion)
                    || f.events != base.events
                {
                    return Err("fault-free distributed replay diverged".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn crash_at_infinity_equals_fault_free_bitwise() {
        // a crash after the last completion never fires: every segment
        // drains to exhaustion first, so the replay is the fault-free
        // run bit-for-bit
        check(
            Config { cases: 16, seed: 0xFA119 },
            "crash at t=∞ == fault-free (bitwise)",
            |rng: &mut Rng| {
                let t = random_tree(TreeClass::Uniform, rng.range(4, 150), rng);
                let alpha = rng.range_f64(0.55, 1.0);
                let nodes = rng.range(2, 5);
                let plat = Platform::Homogeneous { nodes, p: 4.0 };
                let m = map_tree(&t, &plat, alpha, MappingStrategy::Pm, 1.1);
                let victim = rng.below(nodes);
                (t, alpha, plat, m.node_of, victim)
            },
            |(t, alpha, plat, node_of, victim)| {
                let base = simulate_distributed(t, *alpha, plat, node_of, Policy::Pm);
                let trace = FaultTrace::new(vec![FaultEvent {
                    time: 1e300,
                    kind: FaultKind::Crash { node: *victim },
                }]);
                let f = replay_faults_distributed(
                    t,
                    *alpha,
                    plat,
                    node_of,
                    Policy::Pm,
                    &trace,
                    RecoveryPolicy::Best,
                )
                .map_err(|e| e.to_string())?;
                if f.makespan.to_bits() != base.makespan.to_bits()
                    || bits(&f.completion) != bits(&base.completion)
                {
                    return Err("late crash perturbed the run".into());
                }
                if f.lost_work != 0.0 || f.restarted || f.remapped_subtrees != 0 {
                    return Err("late crash charged recovery".into());
                }
                Ok(())
            },
        );
    }

    /// root(2.0)@node0 ← a(0.0)@node1 ← leaf(8.0)@node1, plus
    /// leaf2(8.0)@node0 under the root. α = 1, p = 4 per node: both
    /// leaves finish at t = 2, the zero-length `a` cascades at t = 2,
    /// the root starts at t = 2 and finishes at 2.5.
    fn boundary_fixture() -> (TaskTree, Platform, Vec<usize>) {
        let t = TaskTree::from_parents(&[0, 0, 1, 0], &[2.0, 0.0, 8.0, 8.0]).unwrap();
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let node_of = vec![0, 1, 1, 0];
        (t, plat, node_of)
    }

    #[test]
    fn trace_replay_exports_final_completions_even_after_a_crash() {
        use crate::obs::{chrome_trace, parse_chrome_trace, SpanKind};
        let (t, plat, node_of) = boundary_fixture();
        // fault-free: every span ends at its engine completion time
        let clean = replay_faults_distributed(
            &t,
            1.0,
            &plat,
            &node_of,
            Policy::Pm,
            &FaultTrace::empty(),
            RecoveryPolicy::Best,
        )
        .unwrap();
        let log = trace_replay(&t, &clean);
        log.validate().unwrap();
        assert_eq!(log.spans_of(SpanKind::Factor).count(), t.len());
        for s in log.spans_of(SpanKind::Factor) {
            assert_eq!(s.end.to_bits(), clean.completion[s.task as usize].to_bits());
        }
        assert!((log.makespan() - clean.makespan).abs() < 1e-12);
        // mid-run crash: the re-mapped run still yields a valid,
        // complete log whose tracks follow the *final* assignment
        let trace = FaultTrace::new(vec![FaultEvent {
            time: 1.0,
            kind: FaultKind::Crash { node: 1 },
        }]);
        let f = replay_faults_distributed(
            &t,
            1.0,
            &plat,
            &node_of,
            Policy::Pm,
            &trace,
            RecoveryPolicy::Best,
        )
        .unwrap();
        assert!(f.remapped_subtrees > 0 || f.restarted, "fixture crash was a no-op");
        let flog = trace_replay(&t, &f);
        flog.validate().unwrap();
        assert_eq!(flog.spans_of(SpanKind::Factor).count(), t.len());
        for s in flog.spans_of(SpanKind::Factor) {
            assert_eq!(s.worker as usize, f.node_of[s.task as usize]);
        }
        assert!((flog.makespan() - f.makespan).abs() < 1e-12);
        // and the shared export path round-trips it bit-exactly
        let back = parse_chrome_trace(&chrome_trace(&flog).unwrap()).unwrap();
        assert_eq!(back, flog);
    }

    #[test]
    fn boundary_crash_processes_completion_before_the_event() {
        // the zero-duration-span satellite: a crash landing exactly on
        // the subtree's completion (including its zero-length cascade)
        // must lose nothing
        let (t, plat, node_of) = boundary_fixture();
        let base = simulate_distributed(&t, 1.0, &plat, &node_of, Policy::Pm);
        assert!((base.makespan - 2.5).abs() < 1e-12, "fixture makespan {}", base.makespan);
        let trace = FaultTrace::new(vec![FaultEvent {
            time: 2.0,
            kind: FaultKind::Crash { node: 1 },
        }]);
        let f = replay_faults_distributed(
            &t,
            1.0,
            &plat,
            &node_of,
            Policy::Pm,
            &trace,
            RecoveryPolicy::Best,
        )
        .unwrap();
        assert_eq!(f.lost_work, 0.0, "boundary completion must precede the crash");
        assert_eq!(f.remapped_subtrees, 0);
        assert!(!f.restarted);
        assert!((f.makespan - 2.5).abs() < 1e-12, "makespan {}", f.makespan);
    }

    #[test]
    fn crash_just_before_the_boundary_loses_the_subtree() {
        // control for the tie-break: ε earlier the subtree is still
        // running, so its work is lost and re-run on the survivor
        let (t, plat, node_of) = boundary_fixture();
        let trace = FaultTrace::new(vec![FaultEvent {
            time: 2.0 - 1e-6,
            kind: FaultKind::Crash { node: 1 },
        }]);
        let f = replay_faults_distributed(
            &t,
            1.0,
            &plat,
            &node_of,
            Policy::Pm,
            &trace,
            RecoveryPolicy::Best,
        )
        .unwrap();
        assert!(f.lost_work > 7.9, "nearly all of the leaf is lost, got {}", f.lost_work);
        assert!(f.makespan > 2.5 + 1e-6, "recovery must cost time, got {}", f.makespan);
        assert!(f.node_of.iter().all(|&k| k == 0), "everything ends on the survivor");
    }

    #[test]
    fn best_recovery_never_worse_than_restart() {
        // the acceptance property: candidate selection makes re-mapped
        // recovery ≤ restart-from-scratch on every trace
        check(
            Config { cases: 24, seed: 0xFA120 },
            "Best ≤ RestartOnly",
            |rng: &mut Rng| {
                let classes = [TreeClass::Uniform, TreeClass::Recent, TreeClass::Binary];
                let t = random_tree(classes[rng.below(3)], rng.range(6, 120), rng);
                let alpha = rng.range_f64(0.55, 1.0);
                let nodes = rng.range(2, 4);
                let plat = Platform::Homogeneous { nodes, p: 4.0 };
                let m = map_tree(&t, &plat, alpha, MappingStrategy::Pm, 1.1);
                let victim = rng.below(nodes);
                let frac = rng.range_f64(0.05, 0.95);
                (t, alpha, plat, m.node_of, victim, frac)
            },
            |(t, alpha, plat, node_of, victim, frac)| {
                let base = simulate_distributed(t, *alpha, plat, node_of, Policy::Pm);
                let trace = FaultTrace::new(vec![FaultEvent {
                    time: frac * base.makespan,
                    kind: FaultKind::Crash { node: *victim },
                }]);
                let run = |rec| {
                    replay_faults_distributed(t, *alpha, plat, node_of, Policy::Pm, &trace, rec)
                        .map_err(|e| e.to_string())
                };
                let best = run(RecoveryPolicy::Best)?;
                let restart = run(RecoveryPolicy::RestartOnly)?;
                if best.makespan > restart.makespan * (1.0 + 1e-9) {
                    return Err(format!(
                        "best {} worse than restart {}",
                        best.makespan, restart.makespan
                    ));
                }
                if !best.makespan.is_finite() || best.makespan <= 0.0 {
                    return Err(format!("degenerate recovered makespan {}", best.makespan));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn replay_is_deterministic() {
        let mut rng = Rng::new(0xDE7);
        let t = random_tree(TreeClass::Uniform, 80, &mut rng);
        let plat = Platform::Homogeneous { nodes: 3, p: 4.0 };
        let m = map_tree(&t, &plat, 0.8, MappingStrategy::Pm, 1.1);
        let base = simulate_distributed(&t, 0.8, &plat, &m.node_of, Policy::Pm);
        let trace = FaultTrace::new(vec![
            FaultEvent { time: 0.2 * base.makespan, kind: FaultKind::Slowdown { node: 0, factor: 0.5, duration: 0.2 * base.makespan } },
            FaultEvent { time: 0.4 * base.makespan, kind: FaultKind::Crash { node: 1 } },
            FaultEvent { time: 0.5 * base.makespan, kind: FaultKind::Leave { node: 2, cores: 1.0 } },
            FaultEvent { time: 0.7 * base.makespan, kind: FaultKind::Join { node: 2, cores: 2.0 } },
        ]);
        let a = replay_faults_distributed(
            &t, 0.8, &plat, &m.node_of, Policy::Pm, &trace, RecoveryPolicy::Best,
        )
        .unwrap();
        let b = replay_faults_distributed(
            &t, 0.8, &plat, &m.node_of, Policy::Pm, &trace, RecoveryPolicy::Best,
        )
        .unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(bits(&a.completion), bits(&b.completion));
        assert_eq!(a.lost_work.to_bits(), b.lost_work.to_bits());
        assert!(a.makespan.is_finite() && a.makespan > 0.0);
    }

    #[test]
    fn elastic_capacity_moves_the_makespan_the_right_way() {
        // shared platform (1 node): leaving cores slows the run, a
        // transient slowdown too; joining cores speeds it up
        let mut rng = Rng::new(0xE1A);
        let t = random_tree(TreeClass::Binary, 40, &mut rng);
        let base = simulate(&t, 0.8, 8.0, Policy::Pm);
        let at = 0.3 * base.makespan;
        let run = |kind| {
            let trace = FaultTrace::new(vec![FaultEvent { time: at, kind }]);
            replay_faults(&t, 0.8, 8.0, Policy::Pm, &trace).unwrap()
        };
        let leave = run(FaultKind::Leave { node: 0, cores: 6.0 });
        assert!(leave.makespan > base.makespan * (1.0 + 1e-9), "leave must slow the run");
        let join = run(FaultKind::Join { node: 0, cores: 8.0 });
        assert!(join.makespan < base.makespan * (1.0 - 1e-9), "join must speed the run");
        let slow = run(FaultKind::Slowdown { node: 0, factor: 0.25, duration: 0.2 * base.makespan });
        assert!(slow.makespan > base.makespan * (1.0 + 1e-9), "slowdown must slow the run");
        assert!(slow.makespan < leave.makespan, "a transient hit beats a permanent leave");
        assert_eq!(leave.fault_events, 1);
    }

    #[test]
    fn leave_below_zero_cores_is_rejected() {
        let t = TaskTree::from_parents(&[0, 0], &[1.0, 4.0]).unwrap();
        let trace = FaultTrace::new(vec![FaultEvent {
            time: 0.1,
            kind: FaultKind::Leave { node: 0, cores: 8.0 },
        }]);
        assert!(replay_faults(&t, 0.9, 4.0, Policy::Pm, &trace).is_err());
    }

    #[test]
    fn crashing_every_node_mid_run_is_a_typed_error() {
        // validation rejects all-crash traces up front, but zero-core
        // leaves can still strand a crash with no usable survivor; the
        // engine-level guard must error, never panic (satellite to the
        // remap_lost hardening)
        let t = TaskTree::from_parents(&[0, 0, 0], &[1.0, 8.0, 8.0]).unwrap();
        let plat = Platform::Homogeneous { nodes: 2, p: 4.0 };
        let node_of = vec![0, 0, 1];
        let trace = FaultTrace::new(vec![
            FaultEvent { time: 0.5, kind: FaultKind::Crash { node: 0 } },
            FaultEvent { time: 1.0, kind: FaultKind::Crash { node: 1 } },
        ]);
        assert!(trace.validate(2).is_err(), "validation catches the full crash");
        let err = replay_faults_distributed(
            &t,
            0.9,
            &plat,
            &node_of,
            Policy::Pm,
            &trace,
            RecoveryPolicy::Best,
        );
        assert!(err.is_err(), "engine must reject the trace, not panic");
    }

    #[test]
    fn crash_on_shared_platform_is_rejected_by_validation() {
        let t = TaskTree::from_parents(&[0, 0], &[1.0, 4.0]).unwrap();
        let trace = FaultTrace::new(vec![FaultEvent {
            time: 0.1,
            kind: FaultKind::Crash { node: 0 },
        }]);
        assert!(replay_faults(&t, 0.9, 4.0, Policy::Pm, &trace).is_err());
    }
}
