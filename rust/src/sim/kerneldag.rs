//! Tiled kernel-DAG simulator — the §3 reproduction substrate.
//!
//! The paper measured dense Cholesky / QR / frontal kernels on a
//! 40-core machine under StarPU and showed `T(p) ≈ L / p^α` with
//! α ≈ 0.85–1.0 (Figures 2–6, Tables 1–2). We do not have that
//! machine; what *produces* the `p^α` law is structural — a tiled
//! kernel DAG list-scheduled on `p` cores, slowed by (i) the DAG's
//! critical path when `p` is large relative to the tile count and
//! (ii) contention on shared memory bandwidth. This module simulates
//! exactly that:
//!
//! * DAG builders for right-looking tiled Cholesky, tiled QR
//!   (2D, TS-kernel style) and the qr_mumps-like frontal
//!   factorization with 1D block-column or 2D tile partitioning;
//! * a machine model: `p` cores of unit flop rate + one shared
//!   bandwidth channel with processor-sharing arbitration;
//! * a critical-path-priority list scheduler producing `T(p)`;
//! * [`timing_curve`] sweeping `p` to feed the α regression
//!   ([`crate::metrics::fit_alpha`]).

/// One kernel instance (node of the DAG).
#[derive(Debug, Clone)]
pub struct Kernel {
    /// Compute cost (flops; normalized units).
    pub flops: f64,
    /// Bytes moved to/from shared memory (drives the roofline).
    pub bytes: f64,
    /// Indices of kernels this one depends on.
    pub deps: Vec<u32>,
}

/// A kernel DAG plus bookkeeping.
#[derive(Debug, Clone, Default)]
pub struct KernelDag {
    pub kernels: Vec<Kernel>,
}

/// Machine model for the simulator.
#[derive(Debug, Clone, Copy)]
pub struct MachineModel {
    /// Flops per second per core (normalization: 1.0).
    pub core_rate: f64,
    /// Aggregate shared-memory bandwidth (bytes/s). When the running
    /// set demands more, everyone slows proportionally — this is what
    /// bends the speedup below linear (α < 1) and makes small /
    /// 1D-partitioned matrices worse, as the paper observes.
    pub bandwidth: f64,
}

impl Default for MachineModel {
    fn default() -> Self {
        // Calibrated to the paper's Intel E7-4870 class: ~20 Gflop/s
        // per core (AVX, DGEMM-like kernels) against ~50 GB/s of
        // aggregate socket bandwidth. The ratio is what matters: a
        // b=256 GEMM tile (intensity b/16 = 16 flops/byte) demands
        // 1.25 GB/s per busy core — contention only at high core
        // counts; a b=32 1D panel update (intensity ~5 flops/byte)
        // demands 3.75 GB/s — saturating around 6 cores, which is what
        // drags the paper's 1D α down to 0.78–0.89 (Table 2).
        MachineModel { core_rate: 20.0e9, bandwidth: 24.0e9 }
    }
}

impl KernelDag {
    pub fn push(&mut self, flops: f64, bytes: f64, deps: &[u32]) -> u32 {
        let id = self.kernels.len() as u32;
        self.kernels.push(Kernel { flops, bytes, deps: deps.to_vec() });
        id
    }

    pub fn len(&self) -> usize {
        self.kernels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernels.is_empty()
    }

    pub fn total_flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.flops).sum()
    }

    /// Critical path length in flops (lower bound on any `T(p)`).
    pub fn critical_path(&self) -> f64 {
        let mut cp = vec![0f64; self.len()];
        let mut best: f64 = 0.0;
        for (i, k) in self.kernels.iter().enumerate() {
            let dep_max = k.deps.iter().map(|&d| cp[d as usize]).fold(0.0, f64::max);
            cp[i] = dep_max + k.flops;
            best = best.max(cp[i]);
        }
        best
    }

    /// Right-looking tiled Cholesky of a `t x t` tile matrix with tile
    /// edge `b` (paper Figure 1): POTRF(k); TRSM(i,k) i>k;
    /// SYRK/GEMM(i,j,k) i>=j>k.
    pub fn cholesky(t: usize, b: usize) -> KernelDag {
        let bf = b as f64;
        let tile_bytes = 8.0 * bf * bf;
        let mut dag = KernelDag::default();
        // owner[i][j] = last kernel writing tile (i, j)
        let mut owner: Vec<Vec<Option<u32>>> = vec![vec![None; t]; t];
        for k in 0..t {
            let potrf = {
                let deps: Vec<u32> = owner[k][k].into_iter().collect();
                dag.push(bf * bf * bf / 3.0, 2.0 * tile_bytes, &deps)
            };
            owner[k][k] = Some(potrf);
            for i in k + 1..t {
                let mut deps = vec![potrf];
                deps.extend(owner[i][k]);
                let trsm = dag.push(bf * bf * bf, 3.0 * tile_bytes, &deps);
                owner[i][k] = Some(trsm);
            }
            for i in k + 1..t {
                for j in k + 1..=i {
                    let mut deps = vec![owner[i][k].unwrap(), owner[j][k].unwrap()];
                    deps.extend(owner[i][j]);
                    let flops = if i == j { bf * bf * bf } else { 2.0 * bf * bf * bf };
                    let upd = dag.push(flops, 4.0 * tile_bytes, &deps);
                    owner[i][j] = Some(upd);
                }
            }
        }
        dag
    }

    /// Tiled QR of an `r x c` tile matrix, communication-avoiding
    /// flavor: GEQRT(k,k); ORMQR(k,j) j>k; then the panel below the
    /// diagonal is eliminated by a **binary reduction tree** of
    /// TSQRT merges (log₂ depth — what PLASMA/qr_mumps' tree kernels
    /// do), each merge applying its SSMQR updates to the trailing
    /// tiles of both merged rows.
    pub fn qr(r: usize, c: usize, b: usize) -> KernelDag {
        let bf = b as f64;
        let tile_bytes = 8.0 * bf * bf;
        let steps = r.min(c);
        let mut dag = KernelDag::default();
        let mut owner: Vec<Vec<Option<u32>>> = vec![vec![None; c]; r];
        for k in 0..steps {
            let geqrt = {
                let deps: Vec<u32> = owner[k][k].into_iter().collect();
                dag.push(4.0 / 3.0 * bf * bf * bf, 2.0 * tile_bytes, &deps)
            };
            owner[k][k] = Some(geqrt);
            for j in k + 1..c {
                let mut deps = vec![geqrt];
                deps.extend(owner[k][j]);
                let orm = dag.push(2.0 * bf * bf * bf, 3.0 * tile_bytes, &deps);
                owner[k][j] = Some(orm);
            }
            // binary-tree panel elimination: rows k..r pair up per level
            let mut live: Vec<usize> = (k..r).collect();
            while live.len() > 1 {
                let mut next = Vec::with_capacity(live.len().div_ceil(2));
                for pair in live.chunks(2) {
                    if pair.len() == 1 {
                        next.push(pair[0]);
                        continue;
                    }
                    let (a, bb) = (pair[0], pair[1]);
                    let mut deps: Vec<u32> = Vec::with_capacity(2);
                    deps.extend(owner[a][k]);
                    deps.extend(owner[bb][k]);
                    let tsqrt = dag.push(2.0 * bf * bf * bf, 3.0 * tile_bytes, &deps);
                    owner[a][k] = Some(tsqrt);
                    for j in k + 1..c {
                        let mut deps = vec![tsqrt];
                        deps.extend(owner[a][j]);
                        deps.extend(owner[bb][j]);
                        let ssm = dag.push(4.0 * bf * bf * bf, 4.0 * tile_bytes, &deps);
                        owner[a][j] = Some(ssm);
                        owner[bb][j] = Some(ssm);
                    }
                    next.push(a);
                }
                live = next;
            }
        }
        dag
    }

    /// qr_mumps-style frontal factorization of an `m x n` front.
    /// `partition_1d = true`: block-columns of width `b` (each panel is
    /// one tall kernel + per-column updates — little parallelism,
    /// matching the paper's worse 1D α values); otherwise the 2D tiled
    /// QR above.
    pub fn frontal(m: usize, n: usize, b: usize, partition_1d: bool) -> KernelDag {
        if !partition_1d {
            // auto-tune the tile edge down for skinny fronts: a
            // 1000-column front cut into 256-tiles has only 4 tile
            // columns — no runtime would keep that block size (the
            // paper's footnote: "block sizes were chosen to obtain
            // good performance")
            let b = if n < 8 * b { (n / 8).max(32).min(b) } else { b };
            return Self::qr(m.div_ceil(b), n.div_ceil(b), b);
        }
        // 1D: panels of width b across n columns, each panel factor is
        // sequential over the full height m; updates of the trailing
        // panels are parallel per panel.
        let mut dag = KernelDag::default();
        let panels = n.div_ceil(b);
        let mf = m as f64;
        let bf = b as f64;
        let mut prev_update_of_panel: Vec<Option<u32>> = vec![None; panels];
        let mut last_factor: Option<u32> = None;
        for k in 0..panels {
            let mut deps = Vec::new();
            deps.extend(prev_update_of_panel[k]);
            deps.extend(last_factor);
            // panel factorization: 2 m b^2 flops, touches m x b
            let fac = dag.push(2.0 * mf * bf * bf, 8.0 * mf * bf * 2.0, &deps);
            last_factor = Some(fac);
            for j in k + 1..panels {
                let mut deps = vec![fac];
                deps.extend(prev_update_of_panel[j]);
                let upd = dag.push(4.0 * mf * bf * bf, 8.0 * mf * bf * 3.0, &deps);
                prev_update_of_panel[j] = Some(upd);
            }
        }
        dag
    }
}

/// List-schedule `dag` on `p` cores under `machine`; returns the
/// simulated wall-clock time.
///
/// Scheduler: critical-path priority, non-preemptive, with the shared
/// bandwidth channel arbitrated by processor sharing — each running
/// kernel's service rate is `min(1, bandwidth_share)` where
/// `bandwidth_share = B / Σ demand` of the running set.
pub fn simulate_dag(dag: &KernelDag, p: usize, machine: &MachineModel) -> f64 {
    let n = dag.len();
    if n == 0 {
        return 0.0;
    }
    // priorities: critical path to sink
    let mut prio = vec![0f64; n];
    let mut children: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut indeg = vec![0u32; n];
    for (i, k) in dag.kernels.iter().enumerate() {
        indeg[i] = k.deps.len() as u32;
        for &d in &k.deps {
            children[d as usize].push(i as u32);
        }
    }
    for i in (0..n).rev() {
        let down = children[i]
            .iter()
            .map(|&c| prio[c as usize])
            .fold(0.0, f64::max);
        prio[i] = dag.kernels[i].flops + down;
    }

    // ready heap (max by priority)
    use std::collections::BinaryHeap;
    #[derive(PartialEq)]
    struct Ready(f64, u32);
    impl Eq for Ready {}
    impl PartialOrd for Ready {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Ready {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.0.partial_cmp(&other.0).unwrap()
        }
    }
    let mut ready: BinaryHeap<Ready> = (0..n)
        .filter(|&i| indeg[i] == 0)
        .map(|i| Ready(prio[i], i as u32))
        .collect();

    // running kernels: remaining flops + bytes demand rate
    struct Running {
        id: u32,
        flops_left: f64,
        bytes_per_flop: f64,
    }
    let mut running: Vec<Running> = Vec::with_capacity(p);
    let mut t = 0.0f64;
    let mut done = 0usize;

    while done < n {
        // fill cores
        while running.len() < p {
            let Some(Ready(_, id)) = ready.pop() else { break };
            let k = &dag.kernels[id as usize];
            running.push(Running {
                id,
                flops_left: k.flops.max(1e-12),
                bytes_per_flop: k.bytes / k.flops.max(1e-12),
            });
        }
        assert!(!running.is_empty(), "deadlock in kernel DAG");
        // service rate per kernel under bandwidth sharing:
        // demand_i = core_rate * bytes_per_flop_i; if Σ demand > B,
        // all rates scale by B / Σ demand (processor sharing).
        let total_demand: f64 = running
            .iter()
            .map(|r| machine.core_rate * r.bytes_per_flop)
            .sum();
        let scale = if total_demand > machine.bandwidth {
            machine.bandwidth / total_demand
        } else {
            1.0
        };
        let rate = machine.core_rate * scale;
        // advance to first completion
        let dt = running
            .iter()
            .map(|r| r.flops_left / rate)
            .fold(f64::INFINITY, f64::min);
        t += dt;
        let mut still = Vec::with_capacity(running.len());
        for mut r in running {
            r.flops_left -= dt * rate;
            if r.flops_left <= 1e-9 {
                done += 1;
                for &c in &children[r.id as usize] {
                    indeg[c as usize] -= 1;
                    if indeg[c as usize] == 0 {
                        ready.push(Ready(prio[c as usize], c));
                    }
                }
            } else {
                still.push(r);
            }
        }
        running = still;
    }
    t
}

/// Sweep `p = 1..=p_max`, returning `(p, T(p))` samples.
pub fn timing_curve(dag: &KernelDag, p_max: usize, machine: &MachineModel) -> Vec<(f64, f64)> {
    (1..=p_max)
        .map(|p| (p as f64, simulate_dag(dag, p, machine)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::regression::fit_alpha;

    fn no_bw() -> MachineModel {
        MachineModel { core_rate: 1.0, bandwidth: f64::INFINITY }
    }

    #[test]
    fn single_kernel_runs_at_core_rate() {
        let mut dag = KernelDag::default();
        dag.push(10.0, 0.0, &[]);
        assert!((simulate_dag(&dag, 4, &no_bw()) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn independent_kernels_scale_linearly() {
        let mut dag = KernelDag::default();
        for _ in 0..8 {
            dag.push(5.0, 0.0, &[]);
        }
        assert!((simulate_dag(&dag, 1, &no_bw()) - 40.0).abs() < 1e-9);
        assert!((simulate_dag(&dag, 8, &no_bw()) - 5.0).abs() < 1e-9);
        assert!((simulate_dag(&dag, 4, &no_bw()) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn chain_is_critical_path_bound() {
        let mut dag = KernelDag::default();
        let a = dag.push(3.0, 0.0, &[]);
        let b = dag.push(4.0, 0.0, &[a]);
        dag.push(5.0, 0.0, &[b]);
        assert!((simulate_dag(&dag, 16, &no_bw()) - 12.0).abs() < 1e-9);
        assert!((dag.critical_path() - 12.0).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_cap_limits_throughput() {
        // 4 kernels, each demanding 1 byte per flop, B = 2 bytes/s,
        // 4 cores: rates scale to 1/2 → time doubles vs unbounded.
        let mut dag = KernelDag::default();
        for _ in 0..4 {
            dag.push(10.0, 10.0, &[]);
        }
        let m = MachineModel { core_rate: 1.0, bandwidth: 2.0 };
        let t = simulate_dag(&dag, 4, &m);
        assert!((t - 20.0).abs() < 1e-9, "t={t}");
        // one core at a time is under the cap
        let t1 = simulate_dag(&dag, 1, &m);
        assert!((t1 - 40.0).abs() < 1e-9);
    }

    #[test]
    fn cholesky_dag_has_right_kernel_count() {
        // t tiles: potrf t, trsm t(t-1)/2, syrk/gemm sum_{k} (t-k-1)(t-k)/2
        let t = 5;
        let dag = KernelDag::cholesky(t, 8);
        let potrf = t;
        let trsm = t * (t - 1) / 2;
        let updates: usize = (0..t).map(|k| (t - k - 1) * (t - k) / 2).sum();
        assert_eq!(dag.len(), potrf + trsm + updates);
    }

    #[test]
    fn cholesky_speedup_fits_power_law() {
        // a decently tiled problem should show α close to 1 for small p
        // (b = 256: GEMM-intensity tiles, mild contention — the
        // production configuration of the benches)
        let dag = KernelDag::cholesky(24, 256);
        let curve = timing_curve(&dag, 16, &MachineModel::default());
        let (alpha, fit) = fit_alpha(&curve, 10.0).unwrap();
        assert!(alpha > 0.8 && alpha <= 1.01, "alpha={alpha}");
        assert!(fit.r2 > 0.98, "r2={}", fit.r2);
        // monotone non-increasing timings
        for w in curve.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-9);
        }
    }

    #[test]
    fn small_problem_saturates_early() {
        // few tiles: adding cores beyond the tile parallelism stalls
        let dag = KernelDag::cholesky(4, 32);
        let curve = timing_curve(&dag, 40, &no_bw());
        let t20 = curve[19].1;
        let t40 = curve[39].1;
        assert!((t40 - t20).abs() < 1e-9, "no speedup beyond saturation");
        assert!(t40 >= dag.critical_path() - 1e-9);
    }

    #[test]
    fn qr_dag_nonempty_and_runs() {
        let dag = KernelDag::qr(6, 4, 32);
        assert!(!dag.is_empty());
        let t1 = simulate_dag(&dag, 1, &no_bw());
        let t4 = simulate_dag(&dag, 4, &no_bw());
        assert!(t4 < t1);
        assert!((t1 - dag.total_flops()).abs() < 1e-6 * t1);
    }

    #[test]
    fn frontal_1d_has_less_parallelism_than_2d() {
        let (m, n, b) = (2048, 1024, 128);
        let d1 = KernelDag::frontal(m, n, b, true);
        let d2 = KernelDag::frontal(m, n, b, false);
        let m0 = MachineModel::default();
        let c1 = timing_curve(&d1, 16, &m0);
        let c2 = timing_curve(&d2, 16, &m0);
        let (a1, _) = fit_alpha(&c1, 10.0).unwrap();
        let (a2, _) = fit_alpha(&c2, 10.0).unwrap();
        assert!(a1 < a2, "1D α {a1} should be below 2D α {a2}");
    }
}
