//! Shared timestamped event heap for the simulators.
//!
//! Every DES engine in this crate ([`super::des`], [`super::faults`],
//! [`super::online`], [`crate::net`]) needs the same structure: a
//! min-heap of `(f64 time, payload)` entries popped earliest-first.
//! Before this module each engine carried its own private newtype with
//! a hand-reversed `Ord`; [`EventHeap`] is the one implementation they
//! all share (the first concrete step of the ROADMAP's
//! single-event-core refactor).
//!
//! Ordering: earliest `time` first via [`f64::total_cmp`] (no NaN
//! panics), ties broken by insertion sequence (FIFO). The engines
//! never push NaN times and their results are tie-order independent
//! (same-time completions only feed sums and maxes), so the FIFO
//! tie-break preserves the bitwise guarantees pinned by the engine
//! tests while making pop order fully deterministic by construction.

use std::collections::BinaryHeap;

/// One scheduled event: a time key plus a caller payload.
#[derive(Debug, Clone)]
struct Entry<T> {
    time: f64,
    seq: u64,
    id: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.time.total_cmp(&other.time).is_eq()
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we want earliest first,
        // FIFO among equal times
        other.time.total_cmp(&self.time).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// A deterministic min-heap of timestamped events.
#[derive(Debug, Clone, Default)]
pub struct EventHeap<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T: Copy> EventHeap<T> {
    pub fn new() -> EventHeap<T> {
        EventHeap { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn with_capacity(n: usize) -> EventHeap<T> {
        EventHeap { heap: BinaryHeap::with_capacity(n), seq: 0 }
    }

    /// Schedule `id` at `time`.
    pub fn push(&mut self, time: f64, id: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time, seq, id });
    }

    /// Remove and return the earliest event.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.time, e.id))
    }

    /// The earliest event without removing it.
    pub fn peek(&self) -> Option<(f64, T)> {
        self.heap.peek().map(|e| (e.time, e.id))
    }

    /// Time of the earliest event, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Drop all pending events (the sequence counter keeps running, so
    /// FIFO ties stay globally consistent across rebuilds).
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut h = EventHeap::new();
        h.push(3.0, 30u32);
        h.push(1.0, 10);
        h.push(2.0, 20);
        assert_eq!(h.peek_time(), Some(1.0));
        assert_eq!(h.pop(), Some((1.0, 10)));
        assert_eq!(h.pop(), Some((2.0, 20)));
        assert_eq!(h.pop(), Some((3.0, 30)));
        assert_eq!(h.pop(), None);
        assert!(h.is_empty());
    }

    #[test]
    fn equal_times_pop_fifo() {
        let mut h = EventHeap::new();
        for id in 0..5u32 {
            h.push(7.5, id);
        }
        let order: Vec<u32> = std::iter::from_fn(|| h.pop().map(|(_, id)| id)).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn handles_infinities_and_negative_zero() {
        let mut h = EventHeap::new();
        h.push(f64::INFINITY, 1u32);
        h.push(-0.0, 2);
        h.push(0.0, 3);
        // total_cmp: -0.0 sorts before +0.0
        assert_eq!(h.pop(), Some((-0.0, 2)));
        assert_eq!(h.pop(), Some((0.0, 3)));
        assert_eq!(h.pop(), Some((f64::INFINITY, 1)));
    }

    #[test]
    fn clone_preserves_contents_and_ties() {
        let mut h = EventHeap::new();
        h.push(1.0, 1u32);
        h.push(1.0, 2);
        let mut c = h.clone();
        assert_eq!(c.len(), 2);
        assert_eq!(c.pop(), Some((1.0, 1)));
        assert_eq!(c.pop(), Some((1.0, 2)));
        assert_eq!(h.len(), 2);
    }

    #[test]
    fn clear_empties_the_heap() {
        let mut h = EventHeap::with_capacity(4);
        h.push(1.0, 0u32);
        h.clear();
        assert!(h.is_empty());
        assert_eq!(h.peek_time(), None);
    }
}
