//! DES memory replay: live words over time for any [`Schedule`].
//!
//! Replays a materialized schedule's spans against per-task
//! [`MemWeights`] with the multifrontal pebble-game semantics: at a
//! task's start its front goes live, the children's contribution
//! blocks (live since *their* starts) release during assembly, and the
//! task's own block goes live; at its finish the front releases, the
//! block surviving until the parent's start. The micro-step order
//! within a start matches [`crate::frontal::FrontArena`]'s
//! `begin_front → release children → alloc_block` sequence, so
//! replaying a fully serialized postorder reproduces the
//! arena-measured / `symbolic_peak_f64s` peak **exactly** (tested on a
//! real factorization).
//!
//! With a cap, the replay becomes a frozen-duration rescheduler: a
//! task becomes *eligible* at `max(schedule start, last child
//! finish)` and is admitted FIFO (in schedule-start order) only when
//! both of its start transients fit under the cap; otherwise it
//! stalls until a completion frees memory. When nothing is running
//! and the head task still does not fit, it is force-started (counted
//! in [`MemReplay::forced`]) so an infeasibly small cap degrades into
//! a measured violation instead of a deadlock.

use std::collections::BinaryHeap;

use crate::mem::MemWeights;
use crate::model::TaskTree;
use crate::sched::{Schedule, TaskSpan};

/// Result of a memory replay.
#[derive(Debug, Clone)]
pub struct MemReplay {
    /// Peak live words over the replay.
    pub peak: f64,
    /// Completion time of the last task.
    pub makespan: f64,
    /// Total cap-induced start delay summed over tasks.
    pub stall_time: f64,
    /// Tasks whose start was delayed by the cap.
    pub stalled_tasks: usize,
    /// Force-started tasks (cap too small even with nothing running).
    pub forced: usize,
    /// Events processed (starts + finishes).
    pub events: usize,
    /// `(time, live_words)` after every change, time-ordered.
    pub timeline: Vec<(f64, f64)>,
}

/// Min-heap entry `(time, rank, task)`: finishes (rank 0) before
/// releases (rank 1) at equal times, releases before admissions.
#[derive(PartialEq)]
struct Ev(f64, u8, u32);
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap
        other
            .0
            .total_cmp(&self.0)
            .then(other.1.cmp(&self.1))
            .then(other.2.cmp(&self.2))
    }
}

/// Build global-timeline spans from per-task completion times (e.g. a
/// [`crate::sim::DistDesResult`]'s `completion` vector): a task's span
/// starts when its last child completes — exactly the static-share DES
/// semantics — and finishes at its recorded completion. This is how a
/// *distributed* schedule is replayed for memory: per-node schedules
/// live on node-local timelines, but the DES completion times are
/// global.
pub fn spans_from_completions(tree: &TaskTree, completion: &[f64]) -> Vec<TaskSpan> {
    assert_eq!(completion.len(), tree.len());
    tree.nodes
        .iter()
        .enumerate()
        .map(|(i, node)| {
            let start = node
                .children
                .iter()
                .map(|&c| completion[c as usize])
                .fold(0.0f64, f64::max);
            TaskSpan {
                task: i as u32,
                start,
                finish: completion[i].max(start),
                ratio: 1.0,
            }
        })
        .collect()
}

/// Replay `schedule`'s live words over time; `cap` (words) enables the
/// stalling rescheduler. Tasks missing from the schedule are treated
/// as zero-duration at `t = 0`.
pub fn replay_memory(
    tree: &TaskTree,
    w: &MemWeights,
    schedule: &Schedule,
    cap: Option<f64>,
) -> MemReplay {
    replay_memory_spans(tree, w, &schedule.spans, cap)
}

/// [`replay_memory`] over raw spans (the distributed path pairs this
/// with [`spans_from_completions`]).
pub fn replay_memory_spans(
    tree: &TaskTree,
    w: &MemWeights,
    spans: &[TaskSpan],
    cap: Option<f64>,
) -> MemReplay {
    let n = tree.len();
    debug_assert!(w.front.len() == n && w.cb.len() == n);
    let mut sched_start = vec![0.0f64; n];
    let mut dur = vec![0.0f64; n];
    for s in spans {
        let t = s.task as usize;
        if t < n {
            sched_start[t] = s.start.max(0.0);
            dur[t] = (s.finish - s.start).max(0.0);
        }
    }
    // dispatch priority: schedule start, tie-broken children-first.
    // Starts are clamped non-negative, so their IEEE bit patterns sort
    // numerically and a BTreeSet key gives O(log n) queue ops (wide
    // trees release thousands of tasks at once).
    let mut topo_pos = vec![0usize; n];
    for (i, &v) in tree.topo_up().iter().enumerate() {
        topo_pos[v as usize] = i;
    }
    let prio_key = |v: u32| (sched_start[v as usize].to_bits(), topo_pos[v as usize], v);
    let child_cb_sum: Vec<f64> = tree
        .nodes
        .iter()
        .map(|nd| nd.children.iter().map(|&c| w.cb[c as usize]).sum())
        .collect();

    let mut unfinished: Vec<usize> = tree.nodes.iter().map(|t| t.children.len()).collect();
    let mut child_done = vec![0.0f64; n]; // latest child finish
    let mut eligible_at = vec![0.0f64; n];
    let mut heap: BinaryHeap<Ev> = BinaryHeap::with_capacity(2 * n);
    // admission queue ordered by dispatch priority
    let mut ready: std::collections::BTreeSet<(u64, usize, u32)> = std::collections::BTreeSet::new();
    for v in 0..n as u32 {
        if unfinished[v as usize] == 0 {
            heap.push(Ev(sched_start[v as usize], 1, v));
        }
    }

    let mut live = 0.0f64;
    let mut peak = 0.0f64;
    let mut running = 0usize;
    let mut makespan = 0.0f64;
    let (mut stall_time, mut stalled_tasks, mut forced, mut events) = (0.0, 0usize, 0usize, 0);
    let mut timeline: Vec<(f64, f64)> = Vec::new();

    while let Some(Ev(t, rank, v)) = heap.pop() {
        match rank {
            0 => {
                // finish: the front releases, the block stays
                live -= w.front[v as usize];
                timeline.push((t, live));
                running -= 1;
                makespan = makespan.max(t);
                events += 1;
                if let Some(parent) = tree.nodes[v as usize].parent {
                    let pi = parent as usize;
                    unfinished[pi] -= 1;
                    child_done[pi] = child_done[pi].max(t);
                    if unfinished[pi] == 0 {
                        let rel = sched_start[pi].max(child_done[pi]);
                        heap.push(Ev(rel, 1, parent));
                    }
                }
            }
            _ => {
                // release: the task joins the ready set at its priority
                eligible_at[v as usize] = t;
                ready.insert(prio_key(v));
            }
        }
        // drain events sharing this timestamp before admitting
        if heap.peek().is_some_and(|e| e.0 == t) {
            continue;
        }
        // FIFO admission in priority order
        while let Some(&(_, _, v)) = ready.first() {
            let vi = v as usize;
            // start transients: +front (children blocks still live),
            // then −children blocks +own block
            let t1 = live + w.front[vi];
            let t2 = t1 - child_cb_sum[vi] + w.cb[vi];
            let admit = match cap {
                None => true,
                Some(m) => t1 <= m && t2 <= m,
            };
            if !admit && running > 0 {
                break; // no bypass: wait for a completion
            }
            if !admit {
                forced += 1;
            }
            ready.pop_first();
            let stall = t - eligible_at[vi];
            if stall > 1e-12 * t.abs().max(1.0) {
                stall_time += stall;
                stalled_tasks += 1;
            }
            live += w.front[vi];
            peak = peak.max(live);
            timeline.push((t, live));
            live -= child_cb_sum[vi];
            live += w.cb[vi];
            peak = peak.max(live);
            timeline.push((t, live));
            running += 1;
            events += 1;
            heap.push(Ev(t + dur[vi], 0, v));
        }
    }
    MemReplay {
        peak,
        makespan,
        stall_time,
        stalled_tasks,
        forced,
        events,
        timeline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontal::arena::symbolic_peak_f64s;
    use crate::frontal::multifrontal::{factorize_with_arena, residual};
    use crate::frontal::{FrontArena, RustBackend};
    use crate::mem::{bounded_schedule, liu_order, peak as order_peak};
    use crate::sched::{PmSchedule, Profile};
    use crate::sim::des::{simulate, simulate_distributed, Policy};
    use crate::sparse::{gen, order, symbolic};
    use crate::util::{approx_eq, approx_le};

    /// Serialize `order` into back-to-back unit spans.
    fn serial_schedule(order: &[u32]) -> Schedule {
        Schedule::new(
            order
                .iter()
                .enumerate()
                .map(|(i, &v)| TaskSpan {
                    task: v,
                    start: i as f64,
                    finish: (i + 1) as f64,
                    ratio: 1.0,
                })
                .collect(),
        )
    }

    #[test]
    fn serial_postorder_replay_pins_arena_measured_peak() {
        // the tentpole loop-closer: DES memory replay of the serial
        // postorder == FrontArena measured peak == symbolic prediction,
        // on real factorized grid problems (exact, not approximate)
        for (k, amalg) in [(8usize, 0usize), (10, 4)] {
            let a = gen::grid_laplacian_2d(k);
            let perm = order::nested_dissection_2d(k);
            let at = symbolic::analyze(&a, &perm, amalg).unwrap();
            let ap = a.permute_sym(&at.symbolic.perm).unwrap();
            let mut arena = FrontArena::for_tree(&at);
            let f = factorize_with_arena(&at, &ap, &RustBackend::default(), &mut arena).unwrap();
            assert!(residual(&at, &ap, &f) < 1e-12);

            let w = crate::mem::MemWeights::from_symbolic(&at);
            let replay =
                replay_memory(&at.tree, &w, &serial_schedule(&at.tree.topo_up()), None);
            assert_eq!(replay.peak, arena.peak_f64s() as f64, "grid {k} amalg {amalg}");
            assert_eq!(replay.peak, symbolic_peak_f64s(&at) as f64);
            assert_eq!(replay.stalled_tasks, 0);
            assert_eq!(replay.forced, 0);
            // and the traversal evaluator agrees with the replay
            assert_eq!(
                order_peak(&at.tree, &w, &at.tree.topo_up()),
                replay.peak
            );
        }
    }

    #[test]
    fn liu_serial_replay_matches_traversal_peak() {
        let a = gen::grid_laplacian_3d(6);
        let perm = order::nested_dissection_3d(6);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let w = crate::mem::MemWeights::from_symbolic(&at);
        let liu = liu_order(&at.tree, &w);
        let replay = replay_memory(&at.tree, &w, &serial_schedule(&liu), None);
        assert_eq!(replay.peak, order_peak(&at.tree, &w, &liu));
    }

    #[test]
    fn pm_replay_peak_between_serial_optimum_and_parallel_sum() {
        let a = gen::grid_laplacian_2d(12);
        let perm = order::nested_dissection_2d(12);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let w = crate::mem::MemWeights::from_symbolic(&at);
        let pm = PmSchedule::for_tree(&at.tree, 0.9, &Profile::constant(8.0));
        let r = replay_memory(&at.tree, &w, &pm.schedule, None);
        // the widest single working set is live at some instant; the
        // total of all working sets bounds any concurrency from above
        assert!(r.peak >= w.min_possible_peak());
        let sum: f64 = w.front.iter().zip(&w.cb).map(|(f, c)| f + c).sum();
        assert!(r.peak <= sum);
        // full tree parallelism costs more memory than the optimal
        // serial traversal on this grid (all leaves live at t = 0)
        let liu = order_peak(&at.tree, &w, &liu_order(&at.tree, &w));
        assert!(r.peak > 0.0 && liu > 0.0);
        assert!(approx_eq(r.makespan, pm.schedule.makespan, 1e-9));
        assert_eq!(r.events, 2 * at.tree.len());
    }

    #[test]
    fn cap_induces_stalls_but_never_violations_when_feasible() {
        // wide star: the unbounded PM schedule runs all leaves at once
        let n = 9;
        let parents = vec![0usize; n];
        let lens: Vec<f64> = (0..n).map(|i| 4.0 + i as f64).collect();
        let t = TaskTree::from_parents(&parents, &lens).unwrap();
        let mut w = crate::mem::MemWeights::uniform(n, 50.0, 5.0);
        w.cb[0] = 0.0;
        let pm = PmSchedule::for_tree(&t, 0.8, &Profile::constant(8.0));
        let unbounded = replay_memory(&t, &w, &pm.schedule, None);
        assert!(unbounded.peak > 200.0); // 8 concurrent leaves
        // cap at 3 concurrent working sets: must stall, never exceed
        let cap = 170.0;
        let capped = replay_memory(&t, &w, &pm.schedule, Some(cap));
        assert!(capped.stalled_tasks > 0);
        assert_eq!(capped.forced, 0);
        assert!(capped.peak <= cap + 1e-9, "peak {} over cap", capped.peak);
        assert!(capped.makespan > unbounded.makespan);
        // infeasibly small cap: forced starts, bounded violation count
        let absurd = replay_memory(&t, &w, &pm.schedule, Some(10.0));
        assert!(absurd.forced > 0);
        assert!(absurd.peak >= 55.0);
    }

    #[test]
    fn bounded_schedule_replay_respects_its_cap_under_gating() {
        let a = gen::grid_laplacian_2d(10);
        let perm = order::nested_dissection_2d(10);
        let at = symbolic::analyze(&a, &perm, 2).unwrap();
        let w = crate::mem::MemWeights::from_symbolic(&at);
        let profile = Profile::constant(8.0);
        let unb = bounded_schedule(&at.tree, &w, 0.9, &profile, f64::INFINITY);
        let cap = 0.6 * unb.planned_peak;
        let b = bounded_schedule(&at.tree, &w, 0.9, &profile, cap);
        assert!(b.feasible);
        // hair of slack on the gate: the replay's live accumulates in a
        // different float association than the plan's bound
        let r = replay_memory(&at.tree, &w, &b.schedule, Some(cap * (1.0 + 1e-9)));
        assert!(approx_le(r.peak, cap, 1e-9), "peak {} over cap {cap}", r.peak);
        assert_eq!(r.forced, 0);
        assert_eq!(r.stalled_tasks, 0, "planned schedule should never hit the gate");
    }

    #[test]
    fn distributed_completions_replay_matches_shared_on_one_node() {
        let t = TaskTree::from_parents(&[0, 0, 0, 1, 1], &[6.0, 7.0, 8.0, 9.0, 10.0]).unwrap();
        let w = crate::mem::MemWeights::uniform(5, 12.0, 3.0);
        let plat = crate::model::Platform::Shared { p: 6.0 };
        let dd = simulate_distributed(&t, 0.9, &plat, &[0; 5], Policy::Pm);
        let sd = simulate(&t, 0.9, 6.0, Policy::Pm);
        let from_dist =
            replay_memory_spans(&t, &w, &spans_from_completions(&t, &dd.completion), None);
        let from_shared =
            replay_memory_spans(&t, &w, &spans_from_completions(&t, &sd.completion), None);
        assert_eq!(from_dist.peak.to_bits(), from_shared.peak.to_bits());
        assert_eq!(from_dist.events, from_shared.events);
    }

    #[test]
    fn missing_tasks_are_tolerated_as_zero_duration() {
        let t = TaskTree::from_parents(&[0, 0], &[1.0, 2.0]).unwrap();
        let w = crate::mem::MemWeights::uniform(2, 8.0, 2.0);
        let s = Schedule::new(vec![TaskSpan { task: 1, start: 0.0, finish: 1.0, ratio: 1.0 }]);
        let r = replay_memory(&t, &w, &s, None);
        // leaf runs [0,1); root (missing) starts at its child's finish
        assert_eq!(r.peak, 10.0);
        assert!(approx_eq(r.makespan, 1.0, 1e-12));
    }
}
