//! Job arrivals for the online service: seeded stochastic streams
//! (over the [`crate::workload::generator::ArrivalProcess`] family) and
//! v4 multi-job trace files ([`crate::workload::trace`]).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::model::TaskTree;
use crate::util::rng::Rng;
use crate::workload::generator::{arrival_times, random_tree, ArrivalProcess, TreeClass};
use crate::workload::trace::{read_jobs, TraceJob};

/// One job submitted to the online service. `id`s are dense
/// (`0..n_jobs`) and double as indices into the service's state.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Dense job id (index into the stream).
    pub id: usize,
    /// Owning tenant.
    pub tenant: usize,
    /// Absolute submission time.
    pub arrival: f64,
    /// Scheduling weight (> 0).
    pub priority: f64,
    /// Absolute explicit deadline (`f64::INFINITY` = none; the service
    /// may still imply one via its `deadline_ratio`).
    pub deadline: f64,
    /// The malleable task tree to schedule.
    pub tree: TaskTree,
}

/// Where a `serve` run's jobs come from.
#[derive(Debug, Clone)]
pub enum ArrivalSource {
    /// Generate a synthetic stream from a stochastic process.
    Process(ArrivalProcess),
    /// Replay a v4 multi-job trace file.
    Trace(PathBuf),
}

/// Parse a CLI `--arrivals` spec: `poisson:RATE`, `bursty:RATE:BURST`,
/// `heavy:RATE:SHAPE` or `trace:FILE`. Rates must be finite and
/// positive; burst sizes >= 1; Pareto shapes > 1.
pub fn parse_arrival_spec(spec: &str) -> Result<ArrivalSource> {
    let num = |what: &str, v: &str| -> Result<f64> {
        let x: f64 = v
            .parse()
            .with_context(|| format!("--arrivals {spec:?}: bad {what} {v:?}"))?;
        if !x.is_finite() {
            bail!("--arrivals {spec:?}: {what} must be finite (got {x})");
        }
        Ok(x)
    };
    let toks: Vec<&str> = spec.splitn(2, ':').collect();
    let source = match toks.as_slice() {
        ["trace", path] => return Ok(ArrivalSource::Trace(PathBuf::from(path))),
        _ => {
            let parts: Vec<&str> = spec.split(':').collect();
            match parts.as_slice() {
                ["poisson", r] => {
                    let rate = num("rate", r)?;
                    if rate <= 0.0 {
                        bail!("--arrivals {spec:?}: rate must be > 0 (got {rate})");
                    }
                    ArrivalProcess::Poisson { rate }
                }
                ["bursty", r, b] => {
                    let (rate, burst) = (num("rate", r)?, num("burst size", b)?);
                    if rate <= 0.0 {
                        bail!("--arrivals {spec:?}: rate must be > 0 (got {rate})");
                    }
                    if burst < 1.0 {
                        bail!("--arrivals {spec:?}: burst size must be >= 1 (got {burst})");
                    }
                    ArrivalProcess::Bursty { rate, burst }
                }
                ["heavy", r, a] => {
                    let (rate, shape) = (num("rate", r)?, num("shape", a)?);
                    if rate <= 0.0 {
                        bail!("--arrivals {spec:?}: rate must be > 0 (got {rate})");
                    }
                    if shape <= 1.0 {
                        bail!(
                            "--arrivals {spec:?}: pareto shape must be > 1 so the mean \
                             interarrival exists (got {shape})"
                        );
                    }
                    ArrivalProcess::HeavyTailed { rate, shape }
                }
                _ => bail!(
                    "--arrivals {spec:?}: want poisson:RATE, bursty:RATE:BURST, \
                     heavy:RATE:SHAPE or trace:FILE"
                ),
            }
        }
    };
    Ok(ArrivalSource::Process(source))
}

/// Shape of a synthetic job stream.
#[derive(Debug, Clone)]
pub struct StreamSpec {
    /// Number of jobs to generate.
    pub jobs: usize,
    /// Tenants to spread jobs across (>= 1).
    pub tenants: usize,
    /// Per-job tree size range (log-uniform).
    pub min_nodes: usize,
    pub max_nodes: usize,
    /// RNG seed (arrivals, tenants, priorities and trees all derive
    /// from it).
    pub seed: u64,
}

impl Default for StreamSpec {
    fn default() -> Self {
        StreamSpec { jobs: 200, tenants: 4, min_nodes: 20, max_nodes: 80, seed: 0x0A11 }
    }
}

/// Generate a seeded synthetic job stream: arrival times from
/// `process`, tenants uniform, priorities log-uniform in `[0.5, 2]`,
/// trees drawn from the random-tree classes. Explicit deadlines are
/// left open (`inf`) — the service's `deadline_ratio` implies them.
pub fn job_stream(process: ArrivalProcess, spec: &StreamSpec) -> Vec<JobSpec> {
    assert!(spec.tenants >= 1, "at least one tenant");
    assert!(
        1 <= spec.min_nodes && spec.min_nodes <= spec.max_nodes,
        "node range must satisfy 1 <= min <= max"
    );
    let mut rng = Rng::new(spec.seed);
    let times = arrival_times(process, spec.jobs, &mut rng);
    let classes = [TreeClass::Uniform, TreeClass::Recent, TreeClass::Deep, TreeClass::Binary];
    times
        .into_iter()
        .enumerate()
        .map(|(id, arrival)| {
            let n = rng
                .log_uniform(spec.min_nodes as f64, (spec.max_nodes + 1) as f64)
                .floor() as usize;
            let tree = random_tree(
                classes[rng.below(classes.len())],
                n.clamp(spec.min_nodes, spec.max_nodes),
                &mut rng,
            );
            JobSpec {
                id,
                tenant: rng.below(spec.tenants),
                arrival,
                priority: rng.log_uniform(0.5, 2.0),
                deadline: f64::INFINITY,
                tree,
            }
        })
        .collect()
}

/// Load a v4 trace as a job stream: jobs are sorted by arrival time
/// and re-numbered densely.
pub fn jobs_from_trace(path: &std::path::Path) -> Result<Vec<JobSpec>> {
    let mut jobs: Vec<TraceJob> = read_jobs(path)?;
    jobs.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    Ok(jobs
        .into_iter()
        .enumerate()
        .map(|(id, j)| JobSpec {
            id,
            tenant: j.tenant,
            arrival: j.arrival,
            priority: j.priority,
            deadline: j.deadline,
            tree: j.tree,
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_arrival_specs() {
        match parse_arrival_spec("poisson:3.5").unwrap() {
            ArrivalSource::Process(ArrivalProcess::Poisson { rate }) => assert_eq!(rate, 3.5),
            other => panic!("{other:?}"),
        }
        match parse_arrival_spec("bursty:2:8").unwrap() {
            ArrivalSource::Process(ArrivalProcess::Bursty { rate, burst }) => {
                assert_eq!((rate, burst), (2.0, 8.0));
            }
            other => panic!("{other:?}"),
        }
        match parse_arrival_spec("heavy:1.5:2.5").unwrap() {
            ArrivalSource::Process(ArrivalProcess::HeavyTailed { rate, shape }) => {
                assert_eq!((rate, shape), (1.5, 2.5));
            }
            other => panic!("{other:?}"),
        }
        match parse_arrival_spec("trace:/tmp/x.jobs").unwrap() {
            ArrivalSource::Trace(p) => assert_eq!(p, PathBuf::from("/tmp/x.jobs")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_arrival_spec_rejects_invalid_parameters() {
        for bad in [
            "poisson:0",        // zero rate
            "poisson:-2",       // negative rate
            "poisson:NaN",      // NaN rate
            "poisson:inf",      // infinite rate
            "bursty:2:0.5",     // burst below one
            "heavy:2:1.0",      // shape at the mean-divergence boundary
            "heavy:2:0.5",      // shape below one
            "poisson",          // missing rate
            "sawtooth:2",       // unknown process
            "bursty:2",         // missing burst
        ] {
            assert!(parse_arrival_spec(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn job_streams_are_seeded_and_well_formed() {
        let spec = StreamSpec { jobs: 40, tenants: 3, min_nodes: 5, max_nodes: 30, seed: 11 };
        let a = job_stream(ArrivalProcess::Poisson { rate: 2.0 }, &spec);
        let b = job_stream(ArrivalProcess::Poisson { rate: 2.0 }, &spec);
        assert_eq!(a.len(), 40);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.id, i, "ids are dense");
            assert_eq!(x.arrival, y.arrival, "streams are seeded");
            assert_eq!(x.tree.len(), y.tree.len());
            assert!(x.tenant < 3);
            assert!(x.priority > 0.0 && x.priority.is_finite());
            assert!((5..=30).contains(&x.tree.len()));
            assert_eq!(x.deadline, f64::INFINITY);
            x.tree.validate().unwrap();
        }
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn trace_round_trips_into_a_job_stream() {
        use crate::workload::trace::write_jobs;
        let dir = std::env::temp_dir().join("malltree_online_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.jobs");
        let mut rng = Rng::new(5);
        let jobs: Vec<TraceJob> = [(1usize, 4.0), (0, 1.0), (2, 2.5)]
            .iter()
            .map(|&(tenant, arrival)| TraceJob {
                tenant,
                arrival,
                priority: 1.0,
                deadline: if tenant == 0 { 10.0 } else { f64::INFINITY },
                tree: random_tree(TreeClass::Uniform, 10, &mut rng),
            })
            .collect();
        write_jobs(&jobs, &path).unwrap();
        let stream = jobs_from_trace(&path).unwrap();
        assert_eq!(stream.len(), 3);
        // sorted by arrival, re-numbered densely
        assert_eq!(
            stream.iter().map(|j| (j.id, j.tenant)).collect::<Vec<_>>(),
            vec![(0, 0), (1, 2), (2, 1)]
        );
        assert_eq!(stream[0].deadline, 10.0);
        assert_eq!(stream[2].deadline, f64::INFINITY);
    }
}
