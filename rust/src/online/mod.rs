//! Online multi-tenant scheduling service (DESIGN.md §14).
//!
//! PRs 1–6 schedule one tree (or one fixed batch) per invocation; this
//! module turns the repro into a *service*: a stream of jobs — each a
//! malleable task tree with a tenant, priority and optional deadline —
//! arrives over time ([`arrival`]), and an event-driven front-end
//! ([`service`]) re-solves processor shares at every arrival and
//! completion. Robustness under overload is the headline:
//!
//! * **admission control** — a bounded queue plus a deadline
//!   feasibility estimate from the pooled `L_G/(Σp)^α` lower bound
//!   ([`crate::model::Platform::pooled_lower_bound`]) decide whether a
//!   job may enter;
//! * **backpressure** — when the queue watermark is exceeded the
//!   [`service::OverloadPolicy`] sheds the job, defers it with the
//!   shared bounded linear backoff ([`crate::util::retry`]), or
//!   degrades it to a smaller share weight;
//! * **deadline timeouts** — jobs past their (explicit or
//!   `deadline_ratio`-implied) deadline are cancelled and their shares
//!   reclaimed at the next re-solve;
//! * **fairness modes** — per-tenant weighted-fair shares versus the
//!   global PM makespan split (`rem^{1/α}`-proportional, paper
//!   Lemma 4).
//!
//! The deterministic DES replay lives in [`crate::sim::online`]; the
//! CLI front-end is `malltree serve`.

pub mod arrival;
pub mod service;

pub use arrival::{
    job_stream, jobs_from_trace, parse_arrival_spec, ArrivalSource, JobSpec, StreamSpec,
};
pub use service::{
    Admission, FairnessMode, OnlineService, Outcome, OverloadPolicy, ServiceConfig, ServiceStats,
};
