//! Event-driven scheduling front-end: admission, shares, deadlines.
//!
//! [`OnlineService`] holds the live state of the multi-tenant service:
//! running jobs (each with a processor share and an integer team),
//! a bounded wait queue, and per-job outcomes. The driving simulator
//! ([`crate::sim::online`]) calls [`OnlineService::submit`] at each
//! arrival, [`OnlineService::advance`] to progress work, and
//! [`OnlineService::resolve`] after every state change so shares track
//! the PM-optimal split of the *remaining* work (paper Lemma 4:
//! shares ∝ `rem^{1/α}`). Jobs are reduced to their equivalent length
//! `L_G` at ingest — one `Agreg` + PM solve per job — so the service's
//! per-event work is `O(running jobs)`, not `O(tree nodes)`.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::exec::integer_shares;
use crate::model::{Platform, SpGraph};
use crate::sched::{realistic_speedup, SchedWorkspace};
use crate::util::retry::LinearBackoff;

use super::arrival::JobSpec;

/// Relative tolerance below which remaining work counts as done.
const DONE_TOL: f64 = 1e-9;

/// How shares are split across the running set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FairnessMode {
    /// Two-level weighted fair sharing: tenants split the machine in
    /// proportion to the *mean* priority of their running jobs (so a
    /// tenant cannot grab more by submitting more), then each tenant
    /// splits its budget PM-optimally among its own jobs.
    WeightedFair,
    /// Global makespan mode: one PM split over all running jobs
    /// (weight·`rem^{1/α}`-proportional), ignoring tenant boundaries.
    Makespan,
}

impl FairnessMode {
    pub fn parse(s: &str) -> Result<FairnessMode> {
        match s {
            "fair" => Ok(FairnessMode::WeightedFair),
            "makespan" => Ok(FairnessMode::Makespan),
            _ => bail!("unknown fairness mode {s:?} (want fair or makespan)"),
        }
    }
}

/// What happens to a job that finds the wait queue at its watermark.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Shed immediately.
    Reject,
    /// Ask the client to retry later (bounded linear backoff scaled by
    /// the job's isolated runtime); shed once the budget is exhausted.
    Defer,
    /// Admit into an emergency overflow region (up to twice the queue
    /// watermark) at a degraded share weight; shed beyond that.
    Degrade,
}

impl OverloadPolicy {
    pub fn parse(s: &str) -> Result<OverloadPolicy> {
        match s {
            "reject" => Ok(OverloadPolicy::Reject),
            "defer" => Ok(OverloadPolicy::Defer),
            "degrade" => Ok(OverloadPolicy::Degrade),
            _ => bail!("unknown overload policy {s:?} (want reject, defer or degrade)"),
        }
    }
}

/// Terminal state of a job. Every submitted job ends in exactly one
/// (the conservation property tested in `sim::online`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Ran to completion.
    Completed,
    /// Refused by admission control or backpressure.
    Shed,
    /// Cancelled at its deadline; its share is reclaimed.
    TimedOut,
}

/// Admission verdict returned to the caller at submit/readmit time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Admission {
    /// Running or queued; the service now owns the job.
    Admitted,
    /// Refused (outcome recorded as [`Outcome::Shed`]).
    Shed,
    /// Client should retry at absolute time `until` via
    /// [`OnlineService::readmit`].
    Deferred { until: f64 },
}

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Malleability exponent of the PM model, in `(0, 1]`.
    pub alpha: f64,
    /// Cores of the shared-memory node (integer teams sum to this).
    pub p: usize,
    /// Wait-queue watermark; beyond it the overload policy applies.
    pub queue_cap: usize,
    /// Implied deadline as a multiple of a job's isolated pooled-bound
    /// runtime `T_iso = L/p^α` (`inf` = no implied deadline; explicit
    /// trace deadlines always apply).
    pub deadline_ratio: f64,
    pub mode: FairnessMode,
    pub overload: OverloadPolicy,
    /// Defer backoff: attempt `k` waits `k·base·T_iso` (base is a
    /// fraction of the job's isolated runtime).
    pub defer: LinearBackoff,
    /// Weight multiplier for jobs admitted into the degraded overflow
    /// region, in `(0, 1]`.
    pub degrade_factor: f64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            alpha: crate::DEFAULT_ALPHA,
            p: 8,
            queue_cap: 8,
            deadline_ratio: f64::INFINITY,
            mode: FairnessMode::Makespan,
            overload: OverloadPolicy::Reject,
            defer: LinearBackoff::new(0.5, 3),
            degrade_factor: 0.5,
        }
    }
}

impl ServiceConfig {
    pub fn validate(&self) -> Result<()> {
        if !(self.alpha > 0.0 && self.alpha <= 1.0) {
            bail!("--alpha must be in (0, 1] (got {})", self.alpha);
        }
        if self.p == 0 {
            bail!("-p must be >= 1 core");
        }
        if self.deadline_ratio.is_nan() || self.deadline_ratio <= 0.0 {
            bail!("--deadline-ratio must be > 0 (got {}; inf disables)", self.deadline_ratio);
        }
        if !(self.degrade_factor > 0.0 && self.degrade_factor <= 1.0) {
            bail!("--degrade-factor must be in (0, 1] (got {})", self.degrade_factor);
        }
        Ok(())
    }
}

/// Aggregate counters over a service run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Share re-solves (one per state-changing event batch).
    pub resolves: usize,
    /// Re-solves whose integer team vector changed.
    pub reroundings: usize,
    /// High-water mark of the wait queue.
    pub max_queue: usize,
    pub completed: usize,
    pub shed: usize,
    pub timed_out: usize,
    /// Jobs admitted at a degraded weight.
    pub degraded: usize,
    /// Defer verdicts issued (one job may defer several times).
    pub deferred: usize,
}

/// Live per-job record (indexed by the dense stream id).
#[derive(Debug, Clone)]
struct JobState {
    tenant: usize,
    arrival: f64,
    priority: f64,
    /// Effective absolute deadline (`inf` = none): min of the explicit
    /// trace deadline and the `deadline_ratio`-implied one.
    deadline: f64,
    /// Equivalent length `L_G` at ingest.
    work: f64,
    /// Remaining equivalent length.
    rem: f64,
    /// Share weight (priority, possibly degraded).
    weight: f64,
    /// Defer attempts so far.
    attempts: usize,
    /// Isolated pooled-bound runtime `L/p^α`.
    t_iso: f64,
}

/// The online multi-tenant scheduling service (module docs; DESIGN.md
/// §14). Owns all live job state; a thin DES (`sim::online`) drives it.
#[derive(Debug)]
pub struct OnlineService {
    cfg: ServiceConfig,
    ws: SchedWorkspace,
    jobs: Vec<Option<JobState>>,
    /// Job ids currently holding a share.
    running: Vec<usize>,
    /// Admitted jobs waiting for a slot (ids).
    queue: VecDeque<usize>,
    outcomes: Vec<Option<Outcome>>,
    /// Fractional shares, parallel to `running` (sum = p).
    shares: Vec<f64>,
    /// Integer teams, parallel to `running` (sum = p).
    teams: Vec<usize>,
    /// At most `p` jobs run at once so every team has >= 1 core.
    max_running: usize,
    stats: ServiceStats,
}

impl OnlineService {
    pub fn new(cfg: ServiceConfig) -> Result<OnlineService> {
        cfg.validate()?;
        let max_running = cfg.p;
        Ok(OnlineService {
            cfg,
            ws: SchedWorkspace::new(),
            jobs: Vec::new(),
            running: Vec::new(),
            queue: VecDeque::new(),
            outcomes: Vec::new(),
            shares: Vec::new(),
            teams: Vec::new(),
            max_running,
            stats: ServiceStats::default(),
        })
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    pub fn outcome(&self, id: usize) -> Option<Outcome> {
        self.outcomes.get(id).copied().flatten()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// No job is running or queued (deferred jobs live with the caller).
    pub fn is_idle(&self) -> bool {
        self.running.is_empty() && self.queue.is_empty()
    }

    /// Isolated pooled-bound runtime of a submitted job.
    pub fn t_iso(&self, id: usize) -> f64 {
        self.jobs[id].as_ref().map_or(0.0, |j| j.t_iso)
    }

    /// Submission time of a job.
    pub fn arrival(&self, id: usize) -> f64 {
        self.jobs[id].as_ref().map_or(f64::NAN, |j| j.arrival)
    }

    /// Effective absolute deadline of a job (`inf` = none).
    pub fn deadline(&self, id: usize) -> f64 {
        self.jobs[id].as_ref().map_or(f64::INFINITY, |j| j.deadline)
    }

    fn ensure_id(&mut self, id: usize) {
        if id >= self.jobs.len() {
            self.jobs.resize(id + 1, None);
            self.outcomes.resize(id + 1, None);
        }
    }

    /// Reduce a tree to its equivalent length under `Agreg` (the share
    /// floor the executor enforces), via the reused workspace.
    fn equiv_len(&mut self, job: &JobSpec) -> f64 {
        if job.tree.total_work() == 0.0 {
            return 0.0;
        }
        let g = SpGraph::from_tree(&job.tree);
        let (ag, _) = self.ws.agreg(&g, self.cfg.alpha, self.cfg.p as f64);
        self.ws.solve(&ag, self.cfg.alpha).total_len
    }

    /// Ingest a new arrival at time `t`. Computes the job's equivalent
    /// length and effective deadline, then runs the admission pipeline.
    /// Call [`OnlineService::resolve`] afterwards if `Admitted`.
    pub fn submit(&mut self, t: f64, job: &JobSpec) -> Admission {
        self.ensure_id(job.id);
        let work = self.equiv_len(job);
        let platform = Platform::Shared { p: self.cfg.p as f64 };
        let t_iso = platform.pooled_lower_bound(work, self.cfg.alpha);
        // Zero-work jobs have t_iso = 0; an implied deadline of
        // `arrival + ratio·0` would expire them on arrival, so the
        // ratio only applies to jobs with actual work.
        let implied = if self.cfg.deadline_ratio.is_finite() && t_iso > 0.0 {
            job.arrival + self.cfg.deadline_ratio * t_iso
        } else {
            f64::INFINITY
        };
        self.jobs[job.id] = Some(JobState {
            tenant: job.tenant,
            arrival: job.arrival,
            priority: job.priority,
            deadline: job.deadline.min(implied),
            work,
            rem: work,
            weight: job.priority,
            attempts: 0,
            t_iso,
        });
        self.admit(t, job.id)
    }

    /// Retry a previously [`Admission::Deferred`] job at time `t`.
    pub fn readmit(&mut self, t: f64, id: usize) -> Admission {
        if let Some(v) = self.outcome(id) {
            debug_assert!(false, "readmit of settled job {id} ({v:?})");
            return Admission::Shed;
        }
        self.admit(t, id)
    }

    /// The admission pipeline: deadline feasibility, free slot, queue
    /// room, then the overload policy.
    fn admit(&mut self, t: f64, id: usize) -> Admission {
        let (deadline, t_iso, attempts) = {
            let j = self.jobs[id].as_ref().expect("admit of unknown job");
            (j.deadline, j.t_iso, j.attempts)
        };
        // (0) Already past deadline (a deferred job may come back late).
        if t >= deadline {
            self.settle(id, Outcome::TimedOut);
            return Admission::Shed;
        }
        // (1) Deadline feasibility from the pooled lower bound: even if
        // the whole machine processed the backlog plus this job jointly
        // PM-optimally, would it finish by the deadline? The joint
        // completion is (Σ rem_i^{1/α})^α / p^α (parallel composition).
        if deadline.is_finite() {
            let inv = 1.0 / self.cfg.alpha;
            let mut pooled = self.jobs[id].as_ref().unwrap().rem.powf(inv);
            for &r in self.running.iter().chain(self.queue.iter()) {
                pooled += self.jobs[r].as_ref().unwrap().rem.powf(inv);
            }
            let backlog_done =
                t + pooled.powf(self.cfg.alpha) / (self.cfg.p as f64).powf(self.cfg.alpha);
            if backlog_done > deadline {
                self.settle(id, Outcome::Shed);
                return Admission::Shed;
            }
        }
        // (2) Free slot: run immediately.
        if self.running.len() < self.max_running {
            self.running.push(id);
            return Admission::Admitted;
        }
        // (3) Queue room below the watermark.
        if self.queue.len() < self.cfg.queue_cap {
            self.enqueue(id);
            return Admission::Admitted;
        }
        // (4) Watermark exceeded: the overload policy decides.
        match self.cfg.overload {
            OverloadPolicy::Reject => {
                self.settle(id, Outcome::Shed);
                Admission::Shed
            }
            OverloadPolicy::Defer => {
                let next = attempts + 1;
                match self.cfg.defer.delay(next) {
                    Some(d) => {
                        self.jobs[id].as_mut().unwrap().attempts = next;
                        self.stats.deferred += 1;
                        // scale the unit-agnostic backoff by the job's
                        // own isolated runtime (floored so zero-work
                        // jobs still wait a beat)
                        Admission::Deferred { until: t + d * t_iso.max(1e-6) }
                    }
                    None => {
                        self.settle(id, Outcome::Shed);
                        Admission::Shed
                    }
                }
            }
            OverloadPolicy::Degrade => {
                if self.queue.len() < self.cfg.queue_cap.max(1).saturating_mul(2) {
                    let j = self.jobs[id].as_mut().unwrap();
                    j.weight = j.priority * self.cfg.degrade_factor;
                    self.stats.degraded += 1;
                    self.enqueue(id);
                    Admission::Admitted
                } else {
                    self.settle(id, Outcome::Shed);
                    Admission::Shed
                }
            }
        }
    }

    fn enqueue(&mut self, id: usize) {
        self.queue.push_back(id);
        self.stats.max_queue = self.stats.max_queue.max(self.queue.len());
    }

    fn settle(&mut self, id: usize, outcome: Outcome) {
        debug_assert!(self.outcomes[id].is_none(), "job {id} settled twice");
        self.outcomes[id] = Some(outcome);
        match outcome {
            Outcome::Completed => self.stats.completed += 1,
            Outcome::Shed => self.stats.shed += 1,
            Outcome::TimedOut => self.stats.timed_out += 1,
        }
    }

    /// Progress all running jobs by `dt` under the current shares.
    pub fn advance(&mut self, dt: f64) {
        for (slot, &id) in self.running.iter().enumerate() {
            let share = self.shares.get(slot).copied().unwrap_or(0.0);
            let speed = realistic_speedup(share, self.cfg.alpha);
            let j = self.jobs[id].as_mut().unwrap();
            j.rem = (j.rem - dt * speed).max(0.0);
        }
    }

    /// Time until the first running job finishes under current shares
    /// (`None` when nothing is running).
    pub fn next_completion(&self) -> Option<(f64, usize)> {
        let mut best: Option<(f64, usize)> = None;
        for (slot, &id) in self.running.iter().enumerate() {
            let j = self.jobs[id].as_ref().unwrap();
            let share = self.shares.get(slot).copied().unwrap_or(0.0);
            let speed = realistic_speedup(share, self.cfg.alpha);
            let dt = if j.rem <= DONE_TOL * j.work.max(1.0) {
                0.0
            } else if speed > 0.0 {
                j.rem / speed
            } else {
                continue; // unshared job cannot finish; deadline or resolve rescues it
            };
            if best.is_none() || best.is_some_and(|(b, _)| dt < b) {
                best = Some((dt, id));
            }
        }
        best
    }

    /// Earliest finite deadline over running and queued jobs.
    pub fn next_deadline(&self) -> f64 {
        self.running
            .iter()
            .chain(self.queue.iter())
            .map(|&id| self.jobs[id].as_ref().unwrap().deadline)
            .fold(f64::INFINITY, f64::min)
    }

    /// Settle running jobs whose remaining work is (numerically) zero
    /// as completed, then pull queued jobs into the freed slots
    /// (highest priority first, FIFO on ties). Returns completed ids.
    pub fn reap(&mut self) -> Vec<usize> {
        let mut done = Vec::new();
        let mut slot = 0;
        while slot < self.running.len() {
            let id = self.running[slot];
            let j = self.jobs[id].as_ref().unwrap();
            if j.rem <= DONE_TOL * j.work.max(1.0) {
                self.running.swap_remove(slot);
                self.shares.clear(); // stale slots; resolve() rebuilds
                self.settle(id, Outcome::Completed);
                done.push(id);
            } else {
                slot += 1;
            }
        }
        self.dispatch();
        done
    }

    /// Cancel running/queued jobs whose deadline has passed. Returns
    /// the timed-out ids; their shares are reclaimed at the next
    /// [`OnlineService::resolve`].
    pub fn expire(&mut self, t: f64) -> Vec<usize> {
        let mut out = Vec::new();
        let mut slot = 0;
        while slot < self.running.len() {
            let id = self.running[slot];
            if t >= self.jobs[id].as_ref().unwrap().deadline {
                self.running.swap_remove(slot);
                self.shares.clear();
                self.settle(id, Outcome::TimedOut);
                out.push(id);
            } else {
                slot += 1;
            }
        }
        let mut qi = 0;
        while qi < self.queue.len() {
            let id = self.queue[qi];
            if t >= self.jobs[id].as_ref().unwrap().deadline {
                self.queue.remove(qi);
                self.settle(id, Outcome::TimedOut);
                out.push(id);
            } else {
                qi += 1;
            }
        }
        if !out.is_empty() {
            self.dispatch();
        }
        out
    }

    /// Pull queued jobs into free slots, highest priority first.
    fn dispatch(&mut self) {
        while self.running.len() < self.max_running && !self.queue.is_empty() {
            let best = (0..self.queue.len())
                .max_by(|&a, &b| {
                    let pa = self.jobs[self.queue[a]].as_ref().unwrap().priority;
                    let pb = self.jobs[self.queue[b]].as_ref().unwrap().priority;
                    pa.total_cmp(&pb).then(b.cmp(&a)) // FIFO on ties
                })
                .unwrap();
            let id = self.queue.remove(best).unwrap();
            self.running.push(id);
        }
    }

    /// Re-solve the fractional shares and integer teams of the running
    /// set. Shares follow the PM split of remaining work (Lemma 4)
    /// under the configured fairness mode, then a waterfill pins every
    /// share at >= 1 core (always feasible: at most `p` jobs run).
    pub fn resolve(&mut self) {
        self.stats.resolves += 1;
        let n = self.running.len();
        let old_teams = std::mem::take(&mut self.teams);
        self.shares.clear();
        if n == 0 {
            return;
        }
        let inv = 1.0 / self.cfg.alpha;
        let mut raw: Vec<f64> = match self.cfg.mode {
            FairnessMode::Makespan => self
                .running
                .iter()
                .map(|&id| {
                    let j = self.jobs[id].as_ref().unwrap();
                    j.weight * j.rem.powf(inv)
                })
                .collect(),
            FairnessMode::WeightedFair => {
                // tenant budgets ∝ mean priority of their running jobs
                // (independent of job count); within a tenant, PM split
                // of remaining work
                let mut tenant_w: std::collections::HashMap<usize, (f64, usize, f64)> =
                    std::collections::HashMap::new();
                for &id in &self.running {
                    let j = self.jobs[id].as_ref().unwrap();
                    let e = tenant_w.entry(j.tenant).or_insert((0.0, 0, 0.0));
                    e.0 += j.priority;
                    e.1 += 1;
                    e.2 += j.weight * j.rem.powf(inv);
                }
                self.running
                    .iter()
                    .map(|&id| {
                        let j = self.jobs[id].as_ref().unwrap();
                        let (psum, count, denom) = tenant_w[&j.tenant];
                        if denom > 0.0 {
                            (psum / count as f64) * j.weight * j.rem.powf(inv) / denom
                        } else {
                            0.0
                        }
                    })
                    .collect()
            }
        };
        let sum: f64 = raw.iter().sum();
        let p = self.cfg.p as f64;
        if sum <= 0.0 || !sum.is_finite() {
            raw.iter_mut().for_each(|r| *r = 1.0); // all-finished or degenerate: equal split
        }
        let sum: f64 = raw.iter().sum();
        self.shares.extend(raw.iter().map(|r| r * p / sum));
        // waterfill: lift shares below 1 core to exactly 1, shrinking
        // the others proportionally; converges in <= n rounds
        loop {
            let deficit: f64 = self.shares.iter().filter(|&&s| s < 1.0).map(|s| 1.0 - s).sum();
            if deficit <= 1e-12 {
                break;
            }
            let above: f64 = self.shares.iter().filter(|&&s| s > 1.0).map(|s| s - 1.0).sum();
            if above <= deficit {
                self.shares.iter_mut().for_each(|s| *s = 1.0); // p == n: everyone gets 1... plus slack below
                let spare = p - n as f64;
                if spare > 0.0 {
                    // distribute the leftover evenly (rare: all raw below 1)
                    self.shares.iter_mut().for_each(|s| *s += spare / n as f64);
                }
                break;
            }
            let scale = (above - deficit) / above;
            for s in self.shares.iter_mut() {
                *s = if *s > 1.0 { 1.0 + (*s - 1.0) * scale } else { 1.0 };
            }
        }
        self.teams = integer_shares(&self.shares, self.cfg.p);
        if self.teams != old_teams {
            self.stats.reroundings += 1;
        }
    }

    /// Fractional shares of the running set (parallel to
    /// [`OnlineService::running_ids`]).
    pub fn shares(&self) -> &[f64] {
        &self.shares
    }

    /// Integer core teams of the running set.
    pub fn teams(&self) -> &[usize] {
        &self.teams
    }

    pub fn running_ids(&self) -> &[usize] {
        &self.running
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::generator::{random_tree, TreeClass};

    fn job(id: usize, tenant: usize, arrival: f64, seed: u64) -> JobSpec {
        let mut rng = Rng::new(seed);
        JobSpec {
            id,
            tenant,
            arrival,
            priority: 1.0,
            deadline: f64::INFINITY,
            tree: random_tree(TreeClass::Uniform, 24, &mut rng),
        }
    }

    fn svc(cfg: ServiceConfig) -> OnlineService {
        OnlineService::new(cfg).unwrap()
    }

    #[test]
    fn config_validation_rejects_bad_knobs() {
        for (mutate, what) in [
            (Box::new(|c: &mut ServiceConfig| c.alpha = 0.0) as Box<dyn Fn(&mut ServiceConfig)>, "alpha 0"),
            (Box::new(|c: &mut ServiceConfig| c.alpha = f64::NAN), "alpha NaN"),
            (Box::new(|c: &mut ServiceConfig| c.alpha = 1.5), "alpha 1.5"),
            (Box::new(|c: &mut ServiceConfig| c.p = 0), "p 0"),
            (Box::new(|c: &mut ServiceConfig| c.deadline_ratio = 0.0), "ratio 0"),
            (Box::new(|c: &mut ServiceConfig| c.deadline_ratio = -1.0), "ratio -1"),
            (Box::new(|c: &mut ServiceConfig| c.deadline_ratio = f64::NAN), "ratio NaN"),
            (Box::new(|c: &mut ServiceConfig| c.degrade_factor = 0.0), "degrade 0"),
            (Box::new(|c: &mut ServiceConfig| c.degrade_factor = 2.0), "degrade 2"),
        ] {
            let mut cfg = ServiceConfig::default();
            mutate(&mut cfg);
            assert!(cfg.validate().is_err(), "accepted {what}");
        }
        assert!(ServiceConfig::default().validate().is_ok());
    }

    #[test]
    fn shares_track_remaining_work_and_sum_to_p() {
        let mut s = svc(ServiceConfig { p: 8, ..ServiceConfig::default() });
        for i in 0..3 {
            assert_eq!(s.submit(0.0, &job(i, 0, 0.0, i as u64)), Admission::Admitted);
        }
        s.resolve();
        assert_eq!(s.running_len(), 3);
        let total: f64 = s.shares().iter().sum();
        assert!((total - 8.0).abs() < 1e-9, "shares sum {total}");
        assert!(s.shares().iter().all(|&x| x >= 1.0 - 1e-12), "floor: {:?}", s.shares());
        assert_eq!(s.teams().iter().sum::<usize>(), 8);
        assert!(s.teams().iter().all(|&t| t >= 1));
        // advance until the fastest job finishes; reap dispatches nothing
        let (dt, first) = s.next_completion().unwrap();
        assert!(dt > 0.0);
        s.advance(dt);
        assert_eq!(s.reap(), vec![first]);
        s.resolve();
        assert_eq!(s.running_len(), 2);
        assert!((s.shares().iter().sum::<f64>() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn bounded_queue_sheds_under_reject_policy() {
        let mut s = svc(ServiceConfig {
            p: 2,
            queue_cap: 1,
            overload: OverloadPolicy::Reject,
            ..ServiceConfig::default()
        });
        // 2 run, 1 queues, the 4th is shed
        for i in 0..3 {
            assert_eq!(s.submit(0.0, &job(i, 0, 0.0, i as u64)), Admission::Admitted);
        }
        assert_eq!(s.submit(0.0, &job(3, 0, 0.0, 3)), Admission::Shed);
        assert_eq!(s.outcome(3), Some(Outcome::Shed));
        assert_eq!(s.stats().shed, 1);
        assert_eq!(s.stats().max_queue, 1);
    }

    #[test]
    fn defer_backs_off_linearly_then_sheds() {
        let mut s = svc(ServiceConfig {
            p: 1,
            queue_cap: 0,
            overload: OverloadPolicy::Defer,
            defer: LinearBackoff::new(0.5, 2),
            ..ServiceConfig::default()
        });
        assert_eq!(s.submit(0.0, &job(0, 0, 0.0, 0)), Admission::Admitted);
        let a1 = s.submit(0.0, &job(1, 1, 0.0, 1));
        let t1 = s.t_iso(1).max(1e-6);
        match a1 {
            Admission::Deferred { until } => {
                assert!((until - 0.5 * t1).abs() < 1e-9, "first delay is base x t_iso");
            }
            other => panic!("{other:?}"),
        }
        // retry while still full defers again at twice the delay
        let a2 = s.readmit(1.0, 1);
        match a2 {
            Admission::Deferred { until } => {
                assert!((until - (1.0 + 2.0 * 0.5 * t1)).abs() < 1e-9, "second delay doubles");
            }
            other => panic!("{other:?}"),
        }
        // third attempt exhausts the budget
        assert_eq!(s.readmit(2.0, 1), Admission::Shed);
        assert_eq!(s.outcome(1), Some(Outcome::Shed));
        assert_eq!(s.stats().deferred, 2);
    }

    #[test]
    fn degrade_admits_into_overflow_at_reduced_weight() {
        let mut s = svc(ServiceConfig {
            p: 1,
            queue_cap: 1,
            overload: OverloadPolicy::Degrade,
            degrade_factor: 0.25,
            ..ServiceConfig::default()
        });
        for i in 0..2 {
            assert_eq!(s.submit(0.0, &job(i, 0, 0.0, i as u64)), Admission::Admitted);
        }
        // queue at watermark: next admits degraded into overflow
        assert_eq!(s.submit(0.0, &job(2, 0, 0.0, 2)), Admission::Admitted);
        assert_eq!(s.stats().degraded, 1);
        assert_eq!(s.queue_len(), 2);
        // overflow is bounded at 2× the watermark
        assert_eq!(s.submit(0.0, &job(3, 0, 0.0, 3)), Admission::Shed);
    }

    #[test]
    fn infeasible_deadlines_are_shed_at_admission() {
        let mut s = svc(ServiceConfig {
            p: 4,
            deadline_ratio: 1.05, // barely more than the isolated bound
            ..ServiceConfig::default()
        });
        assert_eq!(s.submit(0.0, &job(0, 0, 0.0, 0)), Admission::Admitted);
        // a second identical job cannot meet 1.05×T_iso with the
        // machine already busy: pooled feasibility sheds it up front
        assert_eq!(s.submit(0.0, &job(1, 0, 0.0, 0)), Admission::Shed);
        assert_eq!(s.outcome(1), Some(Outcome::Shed));
    }

    #[test]
    fn expired_jobs_time_out_and_release_their_share() {
        let mut s = svc(ServiceConfig {
            p: 2,
            deadline_ratio: 2.0,
            ..ServiceConfig::default()
        });
        assert_eq!(s.submit(0.0, &job(0, 0, 0.0, 0)), Admission::Admitted);
        s.resolve();
        let d = s.next_deadline();
        assert!(d.is_finite() && d > 0.0);
        // run past the deadline at an artificially tiny speed by not
        // advancing, then expire
        assert_eq!(s.expire(d), vec![0]);
        assert_eq!(s.outcome(0), Some(Outcome::TimedOut));
        assert_eq!(s.running_len(), 0);
        s.resolve();
        assert!(s.shares().is_empty());
        assert!(s.is_idle());
    }

    #[test]
    fn weighted_fair_splits_by_tenant_budget() {
        // tenant 0 has two running jobs, tenant 1 has one of equal
        // priority: fair mode gives tenant 1's job more than makespan
        // mode would (budgets 2:1 over 3 jobs)
        let mk = |mode| {
            let mut s = svc(ServiceConfig { p: 6, mode, ..ServiceConfig::default() });
            assert_eq!(s.submit(0.0, &job(0, 0, 0.0, 7)), Admission::Admitted);
            assert_eq!(s.submit(0.0, &job(1, 0, 0.0, 7)), Admission::Admitted);
            assert_eq!(s.submit(0.0, &job(2, 1, 0.0, 7)), Admission::Admitted);
            s.resolve();
            s.shares()[2]
        };
        let fair = mk(FairnessMode::WeightedFair);
        let makespan = mk(FairnessMode::Makespan);
        // identical trees: makespan splits 1/3 each; fair gives the
        // lone tenant half the machine
        assert!((makespan - 2.0).abs() < 1e-6, "makespan share {makespan}");
        assert!((fair - 3.0).abs() < 1e-6, "fair share {fair}");
    }

    #[test]
    fn zero_work_jobs_complete_immediately_without_deadline_pathology() {
        let mut s = svc(ServiceConfig {
            p: 2,
            deadline_ratio: 2.0,
            ..ServiceConfig::default()
        });
        let mut j = job(0, 0, 0.0, 0);
        for node in j.tree.nodes.iter_mut() {
            node.len = 0.0;
        }
        assert_eq!(s.submit(0.0, &j), Admission::Admitted);
        s.resolve();
        let (dt, id) = s.next_completion().unwrap();
        assert_eq!((dt, id), (0.0, 0));
        s.advance(dt);
        assert_eq!(s.reap(), vec![0]);
        assert_eq!(s.outcome(0), Some(Outcome::Completed));
    }

    #[test]
    fn mode_and_policy_parsers() {
        assert_eq!(FairnessMode::parse("fair").unwrap(), FairnessMode::WeightedFair);
        assert_eq!(FairnessMode::parse("makespan").unwrap(), FairnessMode::Makespan);
        assert!(FairnessMode::parse("fifo").is_err());
        assert_eq!(OverloadPolicy::parse("reject").unwrap(), OverloadPolicy::Reject);
        assert_eq!(OverloadPolicy::parse("defer").unwrap(), OverloadPolicy::Defer);
        assert_eq!(OverloadPolicy::parse("degrade").unwrap(), OverloadPolicy::Degrade);
        assert!(OverloadPolicy::parse("drop").is_err());
    }
}
