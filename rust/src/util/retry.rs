//! Bounded linear backoff: the one retry-pacing implementation shared
//! by the self-healing executor ([`crate::exec::FaultPlan`], wall-clock
//! milliseconds) and the online service's deferred re-admission
//! ([`crate::online`], virtual seconds). Attempt `k` waits `k × base`,
//! and the budget is `max_retries` attempts — after that the caller
//! gives up (the executor errors the run, the service sheds the job).

/// A bounded linear backoff schedule. `base` is unit-agnostic: the
/// executor feeds milliseconds, the online service virtual seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearBackoff {
    /// Delay added per attempt (attempt `k` waits `k * base`).
    pub base: f64,
    /// Attempts allowed before the budget is exhausted.
    pub max_retries: usize,
}

impl LinearBackoff {
    /// A schedule waiting `k * base` before attempt `k`, for at most
    /// `max_retries` attempts.
    pub fn new(base: f64, max_retries: usize) -> LinearBackoff {
        assert!(base >= 0.0 && base.is_finite(), "backoff base must be finite and >= 0");
        LinearBackoff { base, max_retries }
    }

    /// Delay before the `attempt`-th retry (1-based): `attempt * base`
    /// while the budget lasts, `None` once it is exhausted (attempt 0
    /// is the initial try — it never waits and never consumes budget).
    pub fn delay(&self, attempt: usize) -> Option<f64> {
        (1..=self.max_retries)
            .contains(&attempt)
            .then(|| attempt as f64 * self.base)
    }

    /// Total time a caller can spend backing off if every retry is
    /// needed: `base * (1 + 2 + … + max_retries)`.
    pub fn total_delay(&self) -> f64 {
        let k = self.max_retries as f64;
        self.base * k * (k + 1.0) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_grow_linearly_then_exhaust() {
        let b = LinearBackoff::new(2.0, 3);
        assert_eq!(b.delay(0), None); // the initial try is free
        assert_eq!(b.delay(1), Some(2.0));
        assert_eq!(b.delay(2), Some(4.0));
        assert_eq!(b.delay(3), Some(6.0));
        assert_eq!(b.delay(4), None); // budget exhausted
        assert_eq!(b.total_delay(), 12.0);
    }

    #[test]
    fn zero_base_retries_without_waiting() {
        let b = LinearBackoff::new(0.0, 2);
        assert_eq!(b.delay(1), Some(0.0));
        assert_eq!(b.delay(2), Some(0.0));
        assert_eq!(b.delay(3), None);
        assert_eq!(b.total_delay(), 0.0);
    }

    #[test]
    fn zero_budget_never_retries() {
        let b = LinearBackoff::new(5.0, 0);
        assert_eq!(b.delay(1), None);
        assert_eq!(b.total_delay(), 0.0);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_base() {
        LinearBackoff::new(f64::NAN, 3);
    }
}
