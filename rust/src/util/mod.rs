//! Small in-repo utilities replacing crates unavailable offline:
//! a seedable PRNG (`rng`), a miniature property-testing harness
//! (`prop`), bounded retry backoff (`retry`), float helpers, and
//! text-table rendering support.

pub mod prop;
pub mod retry;
pub mod rng;

/// Relative-tolerance float comparison used across scheduler math.
pub fn approx_eq(a: f64, b: f64, rel: f64) -> bool {
    let scale = a.abs().max(b.abs()).max(1e-12);
    (a - b).abs() <= rel * scale
}

/// `a <= b` up to relative slack (for invariant checks on makespans).
pub fn approx_le(a: f64, b: f64, rel: f64) -> bool {
    a <= b + rel * a.abs().max(b.abs()).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_eq_basics() {
        assert!(approx_eq(1.0, 1.0 + 1e-12, 1e-9));
        assert!(!approx_eq(1.0, 1.1, 1e-9));
        assert!(approx_eq(0.0, 0.0, 1e-9));
        assert!(approx_eq(1e9, 1e9 * (1.0 + 1e-10), 1e-9));
    }

    #[test]
    fn approx_le_basics() {
        assert!(approx_le(1.0, 2.0, 1e-9));
        assert!(approx_le(1.0 + 1e-12, 1.0, 1e-9));
        assert!(!approx_le(1.1, 1.0, 1e-9));
    }
}
