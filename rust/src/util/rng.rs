//! Deterministic, seedable PRNG (xoshiro256++ seeded by SplitMix64).
//!
//! The offline crate set has no `rand`, so the workload generators, the
//! property-test harness and the simulators use this implementation.
//! xoshiro256++ passes BigCrush and is the `rand` crate's own
//! recommendation for non-cryptographic simulation use.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/consecutive seeds give
    /// well-decorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (for per-tree / per-worker rngs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our
    /// non-crypto needs: modulo bias is negligible at n << 2^64).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Log-uniform in `[lo, hi)` — the natural distribution for task
    /// lengths and tree sizes spanning decades.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        (self.range_f64(lo.ln(), hi.ln())).exp()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with given mu/sigma of the underlying normal.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `true` with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn log_uniform_spans_decades() {
        let mut r = Rng::new(5);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            let x = r.log_uniform(1.0, 1e6);
            assert!((1.0..1e6).contains(&x));
            if x < 10.0 {
                lo_seen = true;
            }
            if x > 1e5 {
                hi_seen = true;
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_decorrelates() {
        let mut a = Rng::new(1);
        let mut b = a.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }
}
