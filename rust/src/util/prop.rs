//! Miniature property-testing harness (offline stand-in for `proptest`).
//!
//! `check` runs a property over `n` seeded random cases; on failure it
//! performs a bounded "shrink-lite" pass (retry with smaller size
//! hints) and reports the failing seed so the case is reproducible by
//! construction — every generator takes the [`Rng`] it must derive the
//! case from.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop` on `cases` generated values; panics with the seed of the
/// first failing case.
pub fn check<T, G, P>(cfg: Config, name: &str, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let value = gen(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property `{name}` failed on case {case} (seed {seed}): {msg}\nvalue: {value:?}"
            );
        }
    }
}

/// Convenience: property returning bool.
pub fn check_bool<T, G, P>(cfg: Config, name: &str, gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
    T: std::fmt::Debug,
{
    check(cfg, name, gen, |v| {
        if prop(v) {
            Ok(())
        } else {
            Err("returned false".to_string())
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_bool(
            Config::default(),
            "sum-commutes",
            |r| (r.f64(), r.f64()),
            |&(a, b)| (a + b - (b + a)).abs() < 1e-15,
        );
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn failing_property_reports_seed() {
        check_bool(
            Config { cases: 3, seed: 1 },
            "always-false",
            |r| r.f64(),
            |_| false,
        );
    }
}
